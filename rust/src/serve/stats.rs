//! Virtual-time serving metrics: request latency percentiles, fleet
//! throughput, per-model and per-device accounting, and the cache
//! effectiveness counters — renderable as aligned tables (CLI) or one
//! JSON object (trend tracking across PRs).
//!
//! All latencies are *virtual MCU time*: cycles between a request's
//! arrival and its batch's completion on a device, converted at the
//! paper's 216 MHz clock. Wall-clock appears only as `wall_s`/`wall_ms`
//! (host time spent simulating) and `replay_requests_per_sec` (trace
//! requests replayed per host second — the simulator's own speed, the
//! metric the event-loop trend rows track).

use std::collections::BTreeMap;

use crate::cycles_to_ms;
use crate::util::bench::{percentile, Table};
use crate::util::json::Json;

use super::registry::RegistryStats;

/// Latency distribution summary (milliseconds of virtual MCU time).
#[derive(Debug, Clone, Default)]
pub struct LatencySummary {
    /// Completed requests the summary covers.
    pub count: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    /// Summarize request latencies given in cycles.
    pub fn from_cycles(latencies: &[u64]) -> LatencySummary {
        if latencies.is_empty() {
            return LatencySummary::default();
        }
        let mut ms: Vec<f64> = latencies.iter().map(|&c| cycles_to_ms(c)).collect();
        ms.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
        LatencySummary {
            count: latencies.len() as u64,
            p50_ms: percentile(&ms, 0.50),
            p95_ms: percentile(&ms, 0.95),
            p99_ms: percentile(&ms, 0.99),
            mean_ms: ms.iter().sum::<f64>() / ms.len() as f64,
            max_ms: *ms.last().expect("non-empty"),
        }
    }

    fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("count".into(), Json::Num(self.count as f64));
        o.insert("p50_ms".into(), Json::Num(self.p50_ms));
        o.insert("p95_ms".into(), Json::Num(self.p95_ms));
        o.insert("p99_ms".into(), Json::Num(self.p99_ms));
        o.insert("mean_ms".into(), Json::Num(self.mean_ms));
        o.insert("max_ms".into(), Json::Num(self.max_ms));
        Json::Obj(o)
    }
}

/// Accounting for one served model.
#[derive(Debug, Clone)]
pub struct ModelStats {
    pub label: String,
    pub requests: u64,
    pub batches: u64,
    /// Total device cycles spent on this model (incl. batch overhead),
    /// in each executing device's own cycles.
    pub cycles: u64,
    /// Completed requests that finished past their SLO deadline.
    pub deadline_misses: u64,
    pub cache_hits: u64,
    pub peak_sram: usize,
    pub flash_bytes: usize,
    /// Packing density of the compiled kernels (MACs per SIMD multiply).
    pub macs_per_instr: f64,
}

impl ModelStats {
    /// Mean images per device invocation — the dynamic-batching win.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// Accounting for one fleet device.
#[derive(Debug, Clone)]
pub struct DeviceStats {
    pub id: usize,
    /// Device class label (`m7`, `m4`).
    pub class: String,
    pub batches: u64,
    pub images: u64,
    /// Busy time in shared-timeline reference cycles.
    pub busy_cycles: u64,
    /// Busy fraction of the active span (first arrival → makespan).
    pub utilization: f64,
    /// Pending batches this device stole from backlogged neighbors
    /// (work-stealing mode).
    pub migrations: u64,
    /// Energy this device spent executing (dynamic instruction energy
    /// plus static power over busy time), in joules — priced by the
    /// device target's [`EnergyModel`](crate::target::EnergyModel).
    pub joules: f64,
}

impl DeviceStats {
    /// Mean energy per image executed on this device.
    pub fn joules_per_inference(&self) -> f64 {
        if self.images == 0 {
            0.0
        } else {
            self.joules / self.images as f64
        }
    }
}

/// Everything one trace replay produced.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Scheduling policy that placed the batches.
    pub scheduler: String,
    /// Overload admission policy of the bounded queue.
    pub admission: String,
    /// Requests in the trace.
    pub requests: usize,
    /// Requests that completed an inference.
    pub completed: usize,
    /// Requests shed by the bounded queue.
    pub rejected_queue: u64,
    /// Sheds by SLO class (interactive, standard, batch).
    pub shed_by_class: [u64; 3],
    /// Deadline-carrying sheds by class — every one is an SLO miss that
    /// the completed-request accounting alone would hide.
    pub shed_deadline_by_class: [u64; 3],
    /// Requests rejected because no device's SRAM fits their model.
    pub rejected_sram: u64,
    /// Deadline-carrying SRAM rejections by class — like queue sheds,
    /// these are lost SLOs, not vanished requests.
    pub sram_deadline_by_class: [u64; 3],
    /// Completed requests that finished past their SLO deadline.
    pub deadline_misses: u64,
    /// Completed-late requests by SLO class (interactive, standard,
    /// batch).
    pub miss_by_class: [u64; 3],
    /// Completed-late requests whose inference alone would have met the
    /// deadline — the miss was queueing/batching delay.
    pub miss_queue_wait: u64,
    /// Completed-late requests that could not have met the deadline even
    /// starting at arrival — the miss was compute-bound.
    pub miss_compute: u64,
    /// Preemptive (ahead-of-window) batcher flushes.
    pub preempt_flushes: u64,
    /// Flushed batches split into critical + deferrable halves.
    pub batch_splits: u64,
    /// Pending batches migrated between devices by work stealing.
    pub migrations: u64,
    /// Crash-cancelled requests re-admitted through class-aware
    /// admission, by SLO class (interactive, standard, batch).
    pub readmitted_by_class: [u64; 3],
    /// Requests lost forever to churn (crashed deadline-free members,
    /// batches no live device could host, or re-admission disabled).
    pub lost: u64,
    /// Lost requests by SLO class — every one an unconditional miss.
    pub lost_by_class: [u64; 3],
    /// Device crashes injected over the replay.
    pub crashes: u64,
    /// Standby devices the autoscaler joined.
    pub autoscale_ups: u64,
    /// Standby devices the autoscaler drained back out.
    pub autoscale_downs: u64,
    /// Arrival cycle of the earliest trace request (throughput epoch).
    pub first_arrival_cycles: u64,
    /// Virtual cycle the last batch finished.
    pub makespan_cycles: u64,
    /// Completed requests per second of virtual MCU time.
    pub throughput_rps: f64,
    /// Total fleet energy over the replay (sum of per-device joules).
    pub total_joules: f64,
    pub latency: LatencySummary,
    /// Completed-request latency summaries per SLO class
    /// (0 = interactive, 1 = standard, 2 = batch).
    pub latency_by_class: [LatencySummary; 3],
    pub per_model: Vec<ModelStats>,
    pub per_device: Vec<DeviceStats>,
    pub cache: RegistryStats,
    /// `engine::compile_count` delta over the replay (compile-once proof).
    pub engine_compiles: u64,
    /// Host wall-clock seconds spent simulating.
    pub wall_s: f64,
    /// Host wall-clock milliseconds spent simulating (`wall_s * 1e3`,
    /// carried separately so trend JSON needs no unit conversion).
    pub wall_ms: f64,
    /// Trace requests replayed per host wall-clock second — simulator
    /// speed, as opposed to `throughput_rps` (virtual-time throughput).
    pub replay_requests_per_sec: f64,
}

impl ServeReport {
    /// Active span in cycles: first arrival to last completion. Traces
    /// whose arrivals start late (recorded-trace replays) would deflate
    /// throughput if measured from cycle 0.
    pub fn span_cycles(&self) -> u64 {
        self.makespan_cycles.saturating_sub(self.first_arrival_cycles)
    }

    /// Virtual seconds from the first arrival epoch to makespan.
    pub fn virtual_s(&self) -> f64 {
        self.span_cycles() as f64 / crate::STM32F746_CLOCK_HZ as f64
    }

    /// Shed requests that carried an SLO deadline — misses the bounded
    /// queue caused.
    pub fn shed_deadline_misses(&self) -> u64 {
        self.shed_deadline_by_class.iter().sum()
    }

    /// SRAM-rejected requests that carried an SLO deadline.
    pub fn sram_deadline_misses(&self) -> u64 {
        self.sram_deadline_by_class.iter().sum()
    }

    /// Crash-cancelled requests that re-entered admission, all classes.
    pub fn readmissions(&self) -> u64 {
        self.readmitted_by_class.iter().sum()
    }

    /// Every SLO miss: completed-late plus deadline-carrying sheds,
    /// SRAM rejections, and churn losses — neither admission nor a
    /// crash can hide a lost deadline anywhere.
    pub fn total_misses(&self) -> u64 {
        self.deadline_misses
            + self.shed_deadline_misses()
            + self.sram_deadline_misses()
            + self.lost
    }

    /// Per-class SLO misses, rejection- and loss-inclusive
    /// (0 = interactive, 1 = standard, 2 = batch).
    pub fn class_misses(&self, class_idx: usize) -> u64 {
        self.miss_by_class[class_idx]
            + self.shed_deadline_by_class[class_idx]
            + self.sram_deadline_by_class[class_idx]
            + self.lost_by_class[class_idx]
    }

    /// Mean fleet energy per completed inference, in joules.
    pub fn joules_per_inference(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.total_joules / self.completed as f64
        }
    }

    /// Render the summary + per-model + per-device tables.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "scheduler {}  admission {}  requests {}  completed {}  shed(queue) {}  rejected(sram) {}  deadline misses {}\n",
            self.scheduler,
            self.admission,
            self.requests,
            self.completed,
            self.rejected_queue,
            self.rejected_sram,
            self.deadline_misses
        ));
        out.push_str(&format!(
            "shed by class int/std/batch {}/{}/{} ({} deadline-carrying, {} lost to the SRAM gate)  late by class {}/{}/{}  preempt flushes {}  batch splits {}  migrations {}\n",
            self.shed_by_class[0],
            self.shed_by_class[1],
            self.shed_by_class[2],
            self.shed_deadline_misses(),
            self.sram_deadline_misses(),
            self.miss_by_class[0],
            self.miss_by_class[1],
            self.miss_by_class[2],
            self.preempt_flushes,
            self.batch_splits,
            self.migrations
        ));
        if self.crashes > 0
            || self.lost > 0
            || self.readmissions() > 0
            || self.autoscale_ups > 0
            || self.autoscale_downs > 0
        {
            out.push_str(&format!(
                "churn: crashes {}  readmitted int/std/batch {}/{}/{}  lost {} ({}/{}/{})  autoscale +{}/-{}\n",
                self.crashes,
                self.readmitted_by_class[0],
                self.readmitted_by_class[1],
                self.readmitted_by_class[2],
                self.lost,
                self.lost_by_class[0],
                self.lost_by_class[1],
                self.lost_by_class[2],
                self.autoscale_ups,
                self.autoscale_downs
            ));
        }
        out.push_str(&format!(
            "virtual time {:.3}s  throughput {:.1} req/s  latency p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms (mean {:.2}ms, max {:.2}ms)\n",
            self.virtual_s(),
            self.throughput_rps,
            self.latency.p50_ms,
            self.latency.p95_ms,
            self.latency.p99_ms,
            self.latency.mean_ms,
            self.latency.max_ms
        ));
        for (i, name) in ["interactive", "standard", "batch"].iter().enumerate() {
            let s = &self.latency_by_class[i];
            if s.count > 0 {
                out.push_str(&format!(
                    "  {name:<11} n={}  p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms (mean {:.2}ms, max {:.2}ms)\n",
                    s.count, s.p50_ms, s.p95_ms, s.p99_ms, s.mean_ms, s.max_ms
                ));
            }
        }
        if self.total_misses() > 0 {
            out.push_str(&format!(
                "miss attribution: {} queue-wait, {} compute-bound, {} shed, {} sram (of {} total)\n",
                self.miss_queue_wait,
                self.miss_compute,
                self.shed_deadline_misses(),
                self.sram_deadline_misses(),
                self.total_misses()
            ));
        }
        out.push_str(&format!(
            "energy {:.3} mJ total, {:.4} mJ/inference\n",
            self.total_joules * 1e3,
            self.joules_per_inference() * 1e3
        ));
        out.push_str(&format!(
            "replay host time {:.1}ms  replay speed {:.0} req/s\n",
            self.wall_ms, self.replay_requests_per_sec
        ));
        out.push_str(&format!(
            "artifact cache: {} hits / {} misses ({:.0}% hit rate), {} shared hits, {} compiles, {} evictions (engine compile count +{})\n\n",
            self.cache.hits,
            self.cache.misses,
            self.cache.hit_rate() * 100.0,
            self.cache.shared_hits,
            self.cache.compiles,
            self.cache.evictions,
            self.engine_compiles
        ));

        let mut mt = Table::new(vec![
            "model", "requests", "batches", "mean batch", "cycles", "misses", "cache hits",
            "peak SRAM", "flash", "MACs/instr",
        ]);
        for m in &self.per_model {
            mt.row(vec![
                m.label.clone(),
                format!("{}", m.requests),
                format!("{}", m.batches),
                format!("{:.2}", m.mean_batch()),
                format!("{}", m.cycles),
                format!("{}", m.deadline_misses),
                format!("{}", m.cache_hits),
                format!("{:.1}KB", m.peak_sram as f64 / 1024.0),
                format!("{:.1}KB", m.flash_bytes as f64 / 1024.0),
                format!("{:.2}", m.macs_per_instr),
            ]);
        }
        out.push_str(&mt.render());
        out.push('\n');

        let mut dt = Table::new(vec![
            "device", "class", "batches", "images", "busy cycles", "util", "stolen", "energy",
        ]);
        for d in &self.per_device {
            dt.row(vec![
                format!("mcu{}", d.id),
                d.class.clone(),
                format!("{}", d.batches),
                format!("{}", d.images),
                format!("{}", d.busy_cycles),
                format!("{:.1}%", d.utilization * 100.0),
                format!("{}", d.migrations),
                format!("{:.3}mJ", d.joules * 1e3),
            ]);
        }
        out.push_str(&dt.render());
        out
    }

    /// One JSON object for machine consumption (bench trend lines).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("scheduler".into(), Json::Str(self.scheduler.clone()));
        o.insert("admission".into(), Json::Str(self.admission.clone()));
        o.insert("requests".into(), Json::Num(self.requests as f64));
        o.insert("completed".into(), Json::Num(self.completed as f64));
        o.insert(
            "rejected_queue".into(),
            Json::Num(self.rejected_queue as f64),
        );
        let classes = ["interactive", "standard", "batch"];
        for (i, name) in classes.iter().enumerate() {
            o.insert(
                format!("shed_{name}"),
                Json::Num(self.shed_by_class[i] as f64),
            );
            o.insert(
                format!("late_{name}"),
                Json::Num(self.miss_by_class[i] as f64),
            );
        }
        o.insert(
            "shed_deadline_misses".into(),
            Json::Num(self.shed_deadline_misses() as f64),
        );
        o.insert(
            "sram_deadline_misses".into(),
            Json::Num(self.sram_deadline_misses() as f64),
        );
        o.insert(
            "interactive_misses".into(),
            Json::Num(self.class_misses(0) as f64),
        );
        o.insert("total_misses".into(), Json::Num(self.total_misses() as f64));
        o.insert(
            "preempt_flushes".into(),
            Json::Num(self.preempt_flushes as f64),
        );
        o.insert("batch_splits".into(), Json::Num(self.batch_splits as f64));
        o.insert("migrations".into(), Json::Num(self.migrations as f64));
        o.insert("readmissions".into(), Json::Num(self.readmissions() as f64));
        for (i, name) in classes.iter().enumerate() {
            o.insert(
                format!("readmit_{name}"),
                Json::Num(self.readmitted_by_class[i] as f64),
            );
            o.insert(
                format!("lost_{name}"),
                Json::Num(self.lost_by_class[i] as f64),
            );
        }
        o.insert("lost_requests".into(), Json::Num(self.lost as f64));
        o.insert("crashes".into(), Json::Num(self.crashes as f64));
        o.insert(
            "autoscale_ups".into(),
            Json::Num(self.autoscale_ups as f64),
        );
        o.insert(
            "autoscale_downs".into(),
            Json::Num(self.autoscale_downs as f64),
        );
        o.insert(
            "first_arrival_cycles".into(),
            Json::Num(self.first_arrival_cycles as f64),
        );
        o.insert("rejected_sram".into(), Json::Num(self.rejected_sram as f64));
        o.insert(
            "deadline_misses".into(),
            Json::Num(self.deadline_misses as f64),
        );
        o.insert(
            "makespan_cycles".into(),
            Json::Num(self.makespan_cycles as f64),
        );
        o.insert("virtual_s".into(), Json::Num(self.virtual_s()));
        o.insert("throughput_rps".into(), Json::Num(self.throughput_rps));
        o.insert("total_joules".into(), Json::Num(self.total_joules));
        o.insert(
            "joules_per_inference".into(),
            Json::Num(self.joules_per_inference()),
        );
        o.insert("latency".into(), self.latency.to_json());
        for (i, name) in classes.iter().enumerate() {
            o.insert(
                format!("latency_{name}"),
                self.latency_by_class[i].to_json(),
            );
        }
        o.insert(
            "miss_queue_wait".into(),
            Json::Num(self.miss_queue_wait as f64),
        );
        o.insert("miss_compute".into(), Json::Num(self.miss_compute as f64));
        o.insert(
            "cache_hit_rate".into(),
            Json::Num(self.cache.hit_rate()),
        );
        o.insert("cache_hits".into(), Json::Num(self.cache.hits as f64));
        o.insert(
            "cache_shared_hits".into(),
            Json::Num(self.cache.shared_hits as f64),
        );
        o.insert(
            "cache_compiles".into(),
            Json::Num(self.cache.compiles as f64),
        );
        o.insert(
            "engine_compiles".into(),
            Json::Num(self.engine_compiles as f64),
        );
        o.insert("wall_s".into(), Json::Num(self.wall_s));
        o.insert("wall_ms".into(), Json::Num(self.wall_ms));
        o.insert(
            "replay_requests_per_sec".into(),
            Json::Num(self.replay_requests_per_sec),
        );
        let models: Vec<Json> = self
            .per_model
            .iter()
            .map(|m| {
                let mut mo = BTreeMap::new();
                mo.insert("model".into(), Json::Str(m.label.clone()));
                mo.insert("requests".into(), Json::Num(m.requests as f64));
                mo.insert("batches".into(), Json::Num(m.batches as f64));
                mo.insert("mean_batch".into(), Json::Num(m.mean_batch()));
                mo.insert("cycles".into(), Json::Num(m.cycles as f64));
                mo.insert(
                    "deadline_misses".into(),
                    Json::Num(m.deadline_misses as f64),
                );
                mo.insert("cache_hits".into(), Json::Num(m.cache_hits as f64));
                mo.insert("peak_sram".into(), Json::Num(m.peak_sram as f64));
                mo.insert("flash_bytes".into(), Json::Num(m.flash_bytes as f64));
                mo.insert("macs_per_instr".into(), Json::Num(m.macs_per_instr));
                Json::Obj(mo)
            })
            .collect();
        o.insert("per_model".into(), Json::Arr(models));
        let devices: Vec<Json> = self
            .per_device
            .iter()
            .map(|d| {
                let mut obj = BTreeMap::new();
                obj.insert("device".into(), Json::Num(d.id as f64));
                obj.insert("class".into(), Json::Str(d.class.clone()));
                obj.insert("batches".into(), Json::Num(d.batches as f64));
                obj.insert("images".into(), Json::Num(d.images as f64));
                obj.insert("busy_cycles".into(), Json::Num(d.busy_cycles as f64));
                obj.insert("utilization".into(), Json::Num(d.utilization));
                obj.insert("migrations".into(), Json::Num(d.migrations as f64));
                obj.insert("joules".into(), Json::Num(d.joules));
                obj.insert(
                    "joules_per_inference".into(),
                    Json::Num(d.joules_per_inference()),
                );
                Json::Obj(obj)
            })
            .collect();
        o.insert("per_device".into(), Json::Arr(devices));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary_orders_percentiles() {
        let lat: Vec<u64> = (1..=100).map(|i| i * 216_000).collect(); // 1..100 ms
        let s = LatencySummary::from_cycles(&lat);
        assert!(s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms);
        assert!(s.p99_ms <= s.max_ms);
        assert!((s.p50_ms - 50.5).abs() < 0.6, "p50 {}", s.p50_ms);
        assert!((s.max_ms - 100.0).abs() < 1e-6);
        assert!((s.mean_ms - 50.5).abs() < 1e-6);
    }

    #[test]
    fn empty_latencies_are_zero() {
        let s = LatencySummary::from_cycles(&[]);
        assert_eq!(s.p99_ms, 0.0);
        assert_eq!(s.mean_ms, 0.0);
    }

    fn sample_report() -> ServeReport {
        ServeReport {
            scheduler: "slo-aware".into(),
            admission: "class".into(),
            requests: 10,
            completed: 9,
            rejected_queue: 1,
            shed_by_class: [1, 0, 0],
            shed_deadline_by_class: [1, 0, 0],
            rejected_sram: 1,
            sram_deadline_by_class: [0, 1, 0],
            deadline_misses: 2,
            miss_by_class: [1, 1, 0],
            miss_queue_wait: 1,
            miss_compute: 1,
            preempt_flushes: 1,
            batch_splits: 1,
            migrations: 2,
            readmitted_by_class: [1, 0, 0],
            lost: 1,
            lost_by_class: [0, 0, 1],
            crashes: 1,
            autoscale_ups: 0,
            autoscale_downs: 0,
            first_arrival_cycles: 0,
            makespan_cycles: 216_000_000,
            throughput_rps: 9.0,
            total_joules: 18.0,
            latency: LatencySummary::from_cycles(&[216_000, 432_000]),
            latency_by_class: [
                LatencySummary::from_cycles(&[216_000]),
                LatencySummary::from_cycles(&[432_000]),
                LatencySummary::default(),
            ],
            per_model: vec![ModelStats {
                label: "vgg_tiny/rp-slbc/w4.0a4.0".into(),
                requests: 9,
                batches: 3,
                cycles: 1000,
                deadline_misses: 2,
                cache_hits: 8,
                peak_sram: 2048,
                flash_bytes: 4096,
                macs_per_instr: 3.5,
            }],
            per_device: vec![DeviceStats {
                id: 0,
                class: "m4".into(),
                batches: 3,
                images: 9,
                busy_cycles: 1000,
                utilization: 0.5,
                migrations: 2,
                joules: 18.0,
            }],
            cache: RegistryStats {
                hits: 8,
                misses: 1,
                compiles: 1,
                evictions: 0,
                shared_hits: 0,
                lint_errors: 0,
                lint_warnings: 0,
            },
            engine_compiles: 1,
            wall_s: 0.01,
            wall_ms: 10.0,
            replay_requests_per_sec: 1000.0,
        }
    }

    #[test]
    fn report_renders_and_serializes() {
        let rep = sample_report();
        let txt = rep.render();
        assert!(txt.contains("throughput"));
        assert!(txt.contains("vgg_tiny/rp-slbc"));
        assert!(txt.contains("mcu0"));
        assert!(txt.contains("slo-aware"));
        assert!(txt.contains("admission class"));
        assert!(txt.contains("migrations 2"));
        assert!(txt.contains("m4"));
        let js = rep.to_json().to_string_compact();
        assert!(js.contains("\"throughput_rps\":9"));
        assert!(js.contains("\"per_model\""));
        assert!(js.contains("\"scheduler\":\"slo-aware\""));
        assert!(js.contains("\"admission\":\"class\""));
        assert!(js.contains("\"deadline_misses\":2"));
        assert!(js.contains("\"shed_interactive\":1"));
        assert!(js.contains("\"interactive_misses\":2"));
        assert!(js.contains("\"sram_deadline_misses\":1"));
        assert!(js.contains("\"total_misses\":5"));
        assert!(js.contains("\"migrations\":2"));
        assert!(js.contains("\"readmissions\":1"));
        assert!(js.contains("\"readmit_interactive\":1"));
        assert!(js.contains("\"lost_requests\":1"));
        assert!(js.contains("\"lost_batch\":1"));
        assert!(js.contains("\"crashes\":1"));
        assert!(js.contains("\"autoscale_ups\":0"));
        assert!(txt.contains("churn: crashes 1"), "{txt}");
        assert!(txt.contains("readmitted int/std/batch 1/0/0"), "{txt}");
        assert!(js.contains("\"class\":\"m4\""));
        assert!(js.contains("\"total_joules\":18"));
        assert!(js.contains("\"joules_per_inference\":2"));
        assert!(js.contains("\"latency_interactive\""));
        assert!(js.contains("\"latency_batch\""));
        assert!(js.contains("\"miss_queue_wait\":1"));
        assert!(js.contains("\"miss_compute\":1"));
        assert!(js.contains("\"wall_ms\":10"));
        assert!(js.contains("\"replay_requests_per_sec\":1000"));
        assert!(txt.contains("replay host time 10.0ms"), "{txt}");
        assert!(txt.contains("replay speed 1000 req/s"), "{txt}");
        assert!(txt.contains("interactive"), "{txt}");
        assert!(txt.contains("n=1"), "{txt}");
        assert!(txt.contains("miss attribution: 1 queue-wait, 1 compute-bound"), "{txt}");
        assert!(txt.contains("mJ/inference"));
        assert!((rep.virtual_s() - 1.0).abs() < 1e-9);
        assert_eq!(rep.per_model[0].mean_batch(), 3.0);
        assert_eq!(rep.joules_per_inference(), 2.0);
        assert_eq!(rep.per_device[0].joules_per_inference(), 2.0);
    }

    #[test]
    fn shed_deadlines_count_toward_slo_misses() {
        let rep = sample_report();
        assert_eq!(rep.shed_deadline_misses(), 1);
        assert_eq!(rep.sram_deadline_misses(), 1);
        assert_eq!(
            rep.total_misses(),
            5,
            "2 completed-late + 1 deadline-carrying shed + 1 SRAM-rejected + 1 crash-lost"
        );
        // Interactive: 1 late + 1 shed-with-deadline; standard: 1 late +
        // 1 lost to the SRAM gate; batch: 1 lost to a crash (losses are
        // unconditional misses even for the deadline-free class).
        assert_eq!(rep.class_misses(0), 2);
        assert_eq!(rep.class_misses(1), 2);
        assert_eq!(rep.class_misses(2), 1);
        assert_eq!(rep.readmissions(), 1);
    }

    #[test]
    fn per_class_latency_summaries_track_counts() {
        let rep = sample_report();
        assert_eq!(rep.latency.count, 2);
        assert_eq!(rep.latency_by_class[0].count, 1);
        assert_eq!(rep.latency_by_class[1].count, 1);
        assert_eq!(rep.latency_by_class[2].count, 0);
        // Batch class completed nothing: its summary is all zeros and
        // its render line is suppressed.
        assert_eq!(rep.latency_by_class[2].p99_ms, 0.0);
        let txt = rep.render();
        assert!(!txt.contains("batch       n="), "{txt}");
        // Miss attribution partitions completed-late misses.
        assert_eq!(rep.miss_queue_wait + rep.miss_compute, rep.deadline_misses);
    }

    #[test]
    fn virtual_span_starts_at_first_arrival() {
        let mut rep = sample_report();
        // A recorded trace whose first request arrives half a virtual
        // second in: the active span is what throughput divides by.
        rep.first_arrival_cycles = 108_000_000;
        assert_eq!(rep.span_cycles(), 108_000_000);
        assert!((rep.virtual_s() - 0.5).abs() < 1e-9);
    }
}
