//! Bounded request queue with per-model dynamic batching.
//!
//! Requests for the same [`ModelKey`](super::ModelKey) that arrive within
//! a waiting window are coalesced into one device invocation, amortizing
//! the per-invocation overhead (scheduler entry, activation-arena setup,
//! weight-pointer DMA programming) across the batch. Two admission limits
//! apply: the global bounded queue (`max_queue`, arrivals beyond it are
//! shed) and the per-batch size cap (`max_batch`, a full queue flushes
//! immediately instead of waiting out the window).
//!
//! Everything is virtual-time: a batch's `ready` cycle is the moment its
//! flush condition held — the arrival that filled it, or the oldest
//! member's deadline — so downstream scheduling is exact and
//! deterministic.

use std::collections::VecDeque;

/// Per-invocation overhead charged once per batch (cycles): scheduler
/// entry, arena setup and DMA programming — the fixed cost dynamic
/// batching amortizes. ≈50 µs at 216 MHz.
pub const BATCH_OVERHEAD_CYCLES: u64 = 10_800;

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatcherCfg {
    /// Most images coalesced into one invocation.
    pub max_batch: usize,
    /// Longest a request may wait for co-batching partners (cycles).
    /// ≈2 ms at 216 MHz by default.
    pub max_wait_cycles: u64,
    /// Bounded total queue: arrivals beyond this are shed.
    pub max_queue: usize,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        BatcherCfg {
            max_batch: 8,
            max_wait_cycles: 432_000,
            max_queue: 64,
        }
    }
}

/// One admitted request waiting to be batched.
#[derive(Debug, Clone)]
pub struct PendingRequest {
    pub id: usize,
    /// Index into the workload/key table.
    pub key_idx: usize,
    /// Arrival cycle.
    pub arrival: u64,
    /// Scheduling priority (higher = more urgent; 0 = best effort).
    pub priority: u8,
    /// Absolute SLO deadline (timeline cycles; `u64::MAX` = none).
    pub deadline: u64,
    /// Input image (NHWC flat).
    pub image: Vec<f32>,
}

/// A flushed batch, ready to execute at `ready`.
#[derive(Debug, Clone)]
pub struct ReadyBatch {
    pub key_idx: usize,
    /// Virtual cycle the flush condition held.
    pub ready: u64,
    pub requests: Vec<PendingRequest>,
}

impl ReadyBatch {
    /// Batch priority: the most urgent member's class (dispatch ordering
    /// breaks same-ready ties in favor of higher priority).
    pub fn priority(&self) -> u8 {
        self.requests.iter().map(|r| r.priority).max().unwrap_or(0)
    }
}

/// The per-model waiting queues.
pub struct Batcher {
    cfg: BatcherCfg,
    queues: Vec<VecDeque<PendingRequest>>,
    /// Requests shed by the bounded queue.
    pub shed: u64,
}

impl Batcher {
    pub fn new(cfg: BatcherCfg, num_keys: usize) -> Batcher {
        assert!(cfg.max_batch >= 1, "max_batch must be >= 1");
        assert!(cfg.max_queue >= 1, "max_queue must be >= 1");
        Batcher {
            cfg,
            queues: (0..num_keys).map(|_| VecDeque::new()).collect(),
            shed: 0,
        }
    }

    /// Total queued requests across models.
    pub fn queued(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Admit a request, or shed it when the bounded queue is full.
    /// Returns whether the request was admitted. Flush due batches (via
    /// [`pop_due`](Batcher::pop_due)) *before* offering an arrival so the
    /// bound applies to genuinely concurrent work.
    pub fn offer(&mut self, req: PendingRequest) -> bool {
        if self.queued() >= self.cfg.max_queue {
            self.shed += 1;
            return false;
        }
        self.queues[req.key_idx].push_back(req);
        debug_assert!(self.queued() <= self.cfg.max_queue, "bounded queue invariant");
        true
    }

    /// Flush every batch whose condition holds at virtual time `now`:
    /// full (`max_batch` members, ready = the filling arrival) or
    /// expired (oldest member waited `max_wait_cycles`, ready = its
    /// deadline). Batches come out in key order, oldest first.
    pub fn pop_due(&mut self, now: u64) -> Vec<ReadyBatch> {
        let mut out = Vec::new();
        for (key_idx, q) in self.queues.iter_mut().enumerate() {
            loop {
                let full = q.len() >= self.cfg.max_batch;
                let expired = q
                    .front()
                    .map(|r| r.arrival + self.cfg.max_wait_cycles <= now)
                    .unwrap_or(false);
                if !full && !expired {
                    break;
                }
                let take = q.len().min(self.cfg.max_batch);
                let requests: Vec<PendingRequest> = q.drain(..take).collect();
                let ready = if requests.len() == self.cfg.max_batch {
                    // The arrival that completed the batch triggered it.
                    requests.last().expect("non-empty batch").arrival
                } else {
                    requests.first().expect("non-empty batch").arrival
                        + self.cfg.max_wait_cycles
                };
                out.push(ReadyBatch {
                    key_idx,
                    ready,
                    requests,
                });
            }
        }
        out
    }

    /// Flush everything still queued (end of trace), each remaining
    /// group becoming one batch per `max_batch` slice — full slices were
    /// ready when their last member arrived, partial ones at their
    /// oldest member's deadline.
    pub fn drain_all(&mut self) -> Vec<ReadyBatch> {
        let mut out = Vec::new();
        for (key_idx, q) in self.queues.iter_mut().enumerate() {
            while !q.is_empty() {
                let take = q.len().min(self.cfg.max_batch);
                let requests: Vec<PendingRequest> = q.drain(..take).collect();
                let ready = if requests.len() == self.cfg.max_batch {
                    requests.last().expect("non-empty batch").arrival
                } else {
                    requests.first().expect("non-empty batch").arrival
                        + self.cfg.max_wait_cycles
                };
                out.push(ReadyBatch {
                    key_idx,
                    ready,
                    requests,
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, key_idx: usize, arrival: u64) -> PendingRequest {
        PendingRequest {
            id,
            key_idx,
            arrival,
            priority: 0,
            deadline: u64::MAX,
            image: Vec::new(),
        }
    }

    #[test]
    fn batch_priority_is_the_most_urgent_member() {
        let mut b = Batcher::new(cfg(4, 1000, 16), 1);
        b.offer(req(0, 0, 1));
        b.offer(PendingRequest {
            priority: 2,
            ..req(1, 0, 2)
        });
        let due = b.drain_all();
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].priority(), 2);
    }

    fn cfg(max_batch: usize, max_wait: u64, max_queue: usize) -> BatcherCfg {
        BatcherCfg {
            max_batch,
            max_wait_cycles: max_wait,
            max_queue,
        }
    }

    #[test]
    fn full_batch_flushes_at_filling_arrival() {
        let mut b = Batcher::new(cfg(3, 1000, 16), 1);
        b.offer(req(0, 0, 10));
        b.offer(req(1, 0, 20));
        b.offer(req(2, 0, 30));
        let due = b.pop_due(30);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].requests.len(), 3);
        assert_eq!(due[0].ready, 30, "ready when the third request landed");
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn window_expiry_flushes_partial_batch() {
        let mut b = Batcher::new(cfg(8, 1000, 16), 1);
        b.offer(req(0, 0, 100));
        b.offer(req(1, 0, 400));
        assert!(b.pop_due(1099).is_empty(), "window still open");
        let due = b.pop_due(1100);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].requests.len(), 2);
        assert_eq!(due[0].ready, 1100, "oldest member's deadline");
    }

    #[test]
    fn keys_batch_independently() {
        let mut b = Batcher::new(cfg(2, 1000, 16), 2);
        b.offer(req(0, 0, 10));
        b.offer(req(1, 1, 15));
        b.offer(req(2, 0, 20));
        let due = b.pop_due(20);
        // Key 0 filled (2 members); key 1 still waiting.
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].key_idx, 0);
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn bounded_queue_sheds() {
        let mut b = Batcher::new(cfg(8, 1000, 2), 1);
        assert!(b.offer(req(0, 0, 1)));
        assert!(b.offer(req(1, 0, 2)));
        assert!(!b.offer(req(2, 0, 3)), "third concurrent request is shed");
        assert_eq!(b.shed, 1);
        assert_eq!(b.queued(), 2);
    }

    #[test]
    fn shed_starts_exactly_at_max_queue() {
        // The bound is inclusive: request `max_queue` is admitted,
        // request `max_queue + 1` is shed, and a flush reopens capacity.
        let mut b = Batcher::new(cfg(8, 1_000_000, 3), 2);
        assert!(b.offer(req(0, 0, 0)));
        assert!(b.offer(req(1, 1, 0)));
        assert!(b.offer(req(2, 0, 0)), "bound counts the whole queue, not one key");
        assert!(!b.offer(req(3, 1, 0)));
        assert_eq!((b.queued(), b.shed), (3, 1));
        // Draining key 0 frees two slots; admissions resume.
        let due = b.drain_all();
        assert_eq!(due.iter().map(|d| d.requests.len()).sum::<usize>(), 3);
        assert!(b.offer(req(4, 0, 10)));
        assert_eq!(b.shed, 1, "shed count is cumulative, not reset by drain");
    }

    #[test]
    fn flush_on_full_precedes_deadline_flush_of_younger_requests() {
        // Key 0 fills (flush-on-full, ready = filling arrival); key 1's
        // lone older request must still flush at its own deadline, not
        // ride along early. pop_due returns both; ready times order them.
        let mut b = Batcher::new(cfg(2, 1000, 16), 2);
        b.offer(req(0, 1, 5)); // oldest overall, alone on key 1
        b.offer(req(1, 0, 600));
        b.offer(req(2, 0, 900)); // fills key 0
        let due = b.pop_due(1100);
        assert_eq!(due.len(), 2);
        let full = due.iter().find(|d| d.key_idx == 0).unwrap();
        let expired = due.iter().find(|d| d.key_idx == 1).unwrap();
        assert_eq!(full.ready, 900, "full batch ready at the filling arrival");
        assert_eq!(expired.ready, 5 + 1000, "partial batch ready at its deadline");
        // The full batch became ready before the older request's window
        // closed — downstream ready-time ordering places it first.
        assert!(full.ready < expired.ready);
    }

    #[test]
    fn zero_wait_window_flushes_every_arrival_alone() {
        // max_wait_cycles = 0 degenerates to no batching: each arrival's
        // window has already expired by its own arrival cycle.
        let mut b = Batcher::new(cfg(8, 0, 16), 1);
        b.offer(req(0, 0, 100));
        let due = b.pop_due(100);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].requests.len(), 1);
        assert_eq!(due[0].ready, 100, "zero-wait batch is ready on arrival");
        b.offer(req(1, 0, 100));
        b.offer(req(2, 0, 101));
        // Both pending windows are expired at t=101; they flush as one
        // batch per pop (queue order preserved).
        let due = b.pop_due(101);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].requests.len(), 2);
        assert_eq!(due[0].ready, 100);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn drain_flushes_leftovers_in_slices() {
        let mut b = Batcher::new(cfg(2, 1000, 16), 1);
        for i in 0..5 {
            b.offer(req(i, 0, i as u64));
        }
        // Two full batches flush on demand; one leftover drains.
        let due = b.pop_due(4);
        assert_eq!(due.len(), 2);
        let rest = b.drain_all();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].requests.len(), 1);
        assert_eq!(rest[0].ready, 4 + 1000);
        assert_eq!(b.queued(), 0);
    }
}
