//! Bounded request queue with per-model dynamic batching, class-aware
//! admission and deadline-driven preemption.
//!
//! Requests for the same [`ModelKey`](super::ModelKey) that arrive within
//! a waiting window are coalesced into one device invocation, amortizing
//! the per-invocation overhead (scheduler entry, activation-arena setup,
//! weight-pointer DMA programming) across the batch. Two admission limits
//! apply: the global bounded queue (`max_queue`) and the per-batch size
//! cap (`max_batch`, a full queue flushes immediately instead of waiting
//! out the window).
//!
//! Overload behavior is policy-selectable ([`AdmissionKind`]):
//!
//! * [`Fifo`](AdmissionKind::Fifo) — the original discipline: arrivals
//!   beyond `max_queue` are shed regardless of SLO class.
//! * [`ClassAware`](AdmissionKind::ClassAware) — a full queue sheds
//!   best-effort/batch-class work first: an arriving request evicts the
//!   lowest-priority (then youngest) queued request strictly below its
//!   own class, and is only shed itself when no such victim exists.
//!
//! Sheds are counted per class (and per deadline-carrying class), so a
//! shed request with an SLO deadline is never silently dropped from miss
//! accounting.
//!
//! Crash recovery rides the same admission path: when a device crash
//! cancels an in-flight batch, the replay layer re-[`offer`](Batcher::offer)s
//! each deadline-carrying member — so a re-admission competes with live
//! arrivals under the exact class-aware rules above, and one that loses
//! lands in the ordinary shed counters rather than a side channel.
//!
//! With `preempt` enabled the batcher additionally reacts to deadlines:
//! an arriving request whose deadline cannot survive waiting out the
//! window (given the per-model cost estimate installed via
//! [`set_est_cost`](Batcher::set_est_cost)) triggers a *preemptive
//! flush* — the next [`pop_due`](Batcher::pop_due) pulls it, alone or
//! with same-or-higher-class partners, ahead of the window. Flushed
//! batches that mix deadline-critical and deferrable members can further
//! be split in two by [`split_critical`](Batcher::split_critical), at
//! the price of one extra per-invocation overhead for the deferred half.
//!
//! Everything is virtual-time: a batch's `ready` cycle is the moment its
//! flush condition held — the arrival that filled it, the oldest
//! member's window expiry (clamped to the last member's arrival, so a
//! batch is never ready before a member exists), or the arrival that
//! triggered a preemptive flush — so downstream scheduling is exact and
//! deterministic.

use super::events::{EventHeap, SimEventKind};
use crate::obs::{Event, EventKind};
use std::collections::{BTreeSet, VecDeque};

/// Per-invocation overhead charged once per batch (cycles): scheduler
/// entry, arena setup and DMA programming — the fixed cost dynamic
/// batching amortizes. ≈50 µs at 216 MHz.
pub const BATCH_OVERHEAD_CYCLES: u64 = 10_800;

/// Class index (0 = interactive, 1 = standard, 2 = batch/best-effort)
/// from a scheduling priority (2 = interactive .. 0 = best effort).
/// Shed and miss accounting is reported in class-index order.
pub fn class_index(priority: u8) -> usize {
    2usize.saturating_sub(priority.min(2) as usize)
}

/// Overload admission policy of the bounded queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionKind {
    /// Shed whatever arrives once the queue is full, regardless of class.
    Fifo,
    /// Shed best-effort/batch-class work first: a full queue evicts the
    /// lowest-priority queued request strictly below the arrival's class.
    ClassAware,
}

impl Default for AdmissionKind {
    fn default() -> Self {
        AdmissionKind::Fifo
    }
}

impl AdmissionKind {
    pub const ALL: [AdmissionKind; 2] = [AdmissionKind::Fifo, AdmissionKind::ClassAware];

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionKind::Fifo => "fifo",
            AdmissionKind::ClassAware => "class",
        }
    }

    /// Parse a CLI spelling (`fifo`, `class`, `class-aware`).
    pub fn parse(s: &str) -> Option<AdmissionKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "fifo" => Some(AdmissionKind::Fifo),
            "class" | "class-aware" | "classaware" => Some(AdmissionKind::ClassAware),
            _ => None,
        }
    }
}

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatcherCfg {
    /// Most images coalesced into one invocation.
    pub max_batch: usize,
    /// Longest a request may wait for co-batching partners (cycles).
    /// ≈2 ms at 216 MHz by default.
    pub max_wait_cycles: u64,
    /// Bounded total queue: arrivals beyond this are shed.
    pub max_queue: usize,
    /// Overload shedding discipline.
    pub admission: AdmissionKind,
    /// Deadline-driven preemption: flush window-doomed requests ahead of
    /// the window and allow critical/deferrable batch splitting.
    pub preempt: bool,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        BatcherCfg {
            max_batch: 8,
            max_wait_cycles: 432_000,
            max_queue: 64,
            admission: AdmissionKind::Fifo,
            preempt: false,
        }
    }
}

/// One admitted request waiting to be batched.
#[derive(Debug, Clone)]
pub struct PendingRequest {
    pub id: usize,
    /// Index into the workload/key table.
    pub key_idx: usize,
    /// Arrival cycle.
    pub arrival: u64,
    /// Scheduling priority (higher = more urgent; 0 = best effort).
    pub priority: u8,
    /// Absolute SLO deadline (timeline cycles; `u64::MAX` = none).
    pub deadline: u64,
}

/// A flushed batch, ready to execute at `ready`.
#[derive(Debug, Clone)]
pub struct ReadyBatch {
    pub key_idx: usize,
    /// Virtual cycle the flush condition held.
    pub ready: u64,
    pub requests: Vec<PendingRequest>,
}

impl ReadyBatch {
    /// Batch priority: the most urgent member's class (dispatch ordering
    /// breaks same-ready ties in favor of higher priority).
    pub fn priority(&self) -> u8 {
        self.requests.iter().map(|r| r.priority).max().unwrap_or(0)
    }
}

/// The per-model waiting queues.
pub struct Batcher {
    cfg: BatcherCfg,
    queues: Vec<VecDeque<PendingRequest>>,
    /// Per-key estimated timeline cost `(batch overhead, per image)` on
    /// the fastest fleet device — the preemption doom test's yardstick.
    est: Vec<Option<(u64, u64)>>,
    /// Keys holding a window-doomed request: the next `pop_due` flushes
    /// that class (and above) ahead of the window. Stores the doomed
    /// request's priority.
    urgent: Vec<Option<u8>>,
    /// Requests shed by the bounded queue (either discipline).
    pub shed: u64,
    /// Sheds by class (interactive, standard, batch — `class_index`).
    pub shed_by_class: [u64; 3],
    /// Deadline-carrying sheds by class: every one of these is an SLO
    /// miss the completed-request accounting would otherwise hide.
    pub shed_deadline_by_class: [u64; 3],
    /// Preemptive (ahead-of-window) flushes performed.
    pub preempt_flushes: u64,
    /// Flushed batches split into critical + deferrable halves.
    pub splits: u64,
    /// Observability gate: when set (via [`set_record`](Batcher::set_record))
    /// admission and flush decisions are logged as lifecycle events into
    /// an internal buffer drained by the replay loop. Off by default so
    /// direct users of the batcher (the legacy-pipeline pin) pay nothing.
    record: bool,
    events: Vec<Event>,
    /// Flush due-index: a lazily-deleted min-heap of `(cycle, key)`
    /// entries scheduling the next moment each key *may* have a due
    /// batch (front window expiry, a filling arrival, an urgent
    /// preemption). [`pop_due`](Batcher::pop_due) drains entries at or
    /// before `now` instead of scanning every key; a conservative
    /// (early) entry re-validates against the live queue and re-arms.
    due: EventHeap,
    /// When false, [`pop_due`](Batcher::pop_due) runs the pre-event-loop
    /// full-key scan — kept as the `legacy_loop` baseline the
    /// equivalence tests pin the indexed path against.
    indexed: bool,
    /// Request ids whose arena payload slot can be reclaimed: every
    /// shed arrival and evicted victim lands here. Drained by the
    /// replay loop via [`drain_reclaimed`](Batcher::drain_reclaimed).
    reclaimed: Vec<usize>,
}

impl Batcher {
    pub fn new(cfg: BatcherCfg, num_keys: usize) -> Batcher {
        assert!(cfg.max_batch >= 1, "max_batch must be >= 1");
        assert!(cfg.max_queue >= 1, "max_queue must be >= 1");
        Batcher {
            cfg,
            queues: (0..num_keys).map(|_| VecDeque::new()).collect(),
            est: vec![None; num_keys],
            urgent: vec![None; num_keys],
            shed: 0,
            shed_by_class: [0; 3],
            shed_deadline_by_class: [0; 3],
            preempt_flushes: 0,
            splits: 0,
            record: false,
            events: Vec::new(),
            due: EventHeap::new(),
            indexed: true,
            reclaimed: Vec::new(),
        }
    }

    /// Select the flush-scan strategy: indexed (the event-heap
    /// due-index, default) or the legacy linear pass over every key.
    /// Both produce identical batches at identical cycles — the indexed
    /// path only skips keys that provably have nothing due.
    pub fn set_indexed(&mut self, on: bool) {
        self.indexed = on;
    }

    /// Enable/disable lifecycle-event logging (`Admit`/`Evict`/`Shed`/
    /// `Flush*`). Purely passive: no admission or flush decision reads
    /// the log.
    pub fn set_record(&mut self, on: bool) {
        self.record = on;
    }

    /// Take all events logged since the last drain, in decision order.
    pub fn drain_events(&mut self) -> Vec<Event> {
        std::mem::take(&mut self.events)
    }

    /// Log one request-scoped event at virtual time `cycles`.
    fn log_req(&mut self, cycles: u64, r: &PendingRequest, kind: EventKind) {
        if self.record {
            self.events.push(Event {
                cycles,
                id: r.id,
                key_idx: r.key_idx,
                class: class_index(r.priority) as u8,
                kind,
            });
        }
    }

    /// Log one batch-scoped flush event: stamped with the batch's ready
    /// cycle, first member's id and the batch's effective class.
    fn log_flush(&mut self, batch: &ReadyBatch, kind: EventKind) {
        if self.record {
            self.events.push(Event {
                cycles: batch.ready,
                id: batch.requests.first().map_or(0, |r| r.id),
                key_idx: batch.key_idx,
                class: class_index(batch.priority()) as u8,
                kind,
            });
        }
    }

    /// Total queued requests across models.
    pub fn queued(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Install the estimated timeline cost of serving `key_idx`: the
    /// per-batch base overhead and the per-image increment, both on the
    /// fastest fleet device. Enables the preemption doom test.
    pub fn set_est_cost(&mut self, key_idx: usize, base: u64, per_image: u64) {
        self.est[key_idx] = Some((base, per_image));
    }

    fn count_shed(&mut self, r: &PendingRequest) {
        self.shed += 1;
        let c = class_index(r.priority);
        self.shed_by_class[c] += 1;
        if r.deadline != u64::MAX {
            self.shed_deadline_by_class[c] += 1;
        }
        self.reclaimed.push(r.id);
    }

    /// Ids of requests shed/evicted since the last drain — their arena
    /// payload slots will never be executed and can be released.
    pub fn drain_reclaimed(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.reclaimed)
    }

    /// Lowest-priority queued request strictly below `priority` —
    /// the class-aware eviction victim. Ties prefer the youngest
    /// (latest-arrival, then highest-id) request: it has sunk the least
    /// waiting time.
    fn victim_below(&self, priority: u8) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize, u8, u64, usize)> = None;
        for (k, q) in self.queues.iter().enumerate() {
            for (pos, r) in q.iter().enumerate() {
                if r.priority >= priority {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some((_, _, bp, ba, bid)) => {
                        (r.priority, std::cmp::Reverse(r.arrival), std::cmp::Reverse(r.id))
                            < (bp, std::cmp::Reverse(ba), std::cmp::Reverse(bid))
                    }
                };
                if better {
                    best = Some((k, pos, r.priority, r.arrival, r.id));
                }
            }
        }
        best.map(|(k, pos, ..)| (k, pos))
    }

    /// Would waiting out the window doom this request's deadline? Uses
    /// the optimistic per-key cost estimate (fastest device, current
    /// co-batch size); without an estimate the test is inert. An arrival
    /// that *fills* the batch is never doomed — the full batch flushes
    /// right now anyway, carrying every member, so a class-filtered
    /// preemptive flush would only strand lower-class partners.
    fn window_doomed(&self, req: &PendingRequest) -> bool {
        if req.deadline == u64::MAX {
            return false;
        }
        let Some((base, per_image)) = self.est[req.key_idx] else {
            return false;
        };
        let q = &self.queues[req.key_idx];
        if q.len() + 1 >= self.cfg.max_batch {
            return false;
        }
        let oldest = q.front().map_or(req.arrival, |r| r.arrival);
        let expiry = oldest.saturating_add(self.cfg.max_wait_cycles);
        let members = q.len() as u64 + 1;
        expiry
            .saturating_add(base)
            .saturating_add(per_image.saturating_mul(members))
            > req.deadline
    }

    /// Admit a request, or shed (FIFO) / evict a lower-class victim
    /// (class-aware) when the bounded queue is full. Returns whether the
    /// request was admitted. Flush due batches (via
    /// [`pop_due`](Batcher::pop_due)) *before* offering an arrival so the
    /// bound applies to genuinely concurrent work.
    pub fn offer(&mut self, req: PendingRequest) -> bool {
        if self.queued() >= self.cfg.max_queue {
            let victim = match self.cfg.admission {
                AdmissionKind::Fifo => None,
                AdmissionKind::ClassAware => self.victim_below(req.priority),
            };
            match victim {
                Some((k, pos)) => {
                    let evicted = self.queues[k].remove(pos).expect("victim position valid");
                    self.count_shed(&evicted);
                    self.log_req(
                        req.arrival,
                        &evicted,
                        EventKind::Evict {
                            had_deadline: evicted.deadline != u64::MAX,
                        },
                    );
                }
                None => {
                    self.count_shed(&req);
                    self.log_req(
                        req.arrival,
                        &req,
                        EventKind::Shed {
                            had_deadline: req.deadline != u64::MAX,
                        },
                    );
                    return false;
                }
            }
        }
        let mut due_now = false;
        if self.cfg.preempt && self.window_doomed(&req) {
            let u = &mut self.urgent[req.key_idx];
            *u = Some(u.map_or(req.priority, |p| p.max(req.priority)));
            due_now = true;
        }
        self.log_req(req.arrival, &req, EventKind::Admit);
        let key_idx = req.key_idx;
        let arrival = req.arrival;
        let was_empty = self.queues[key_idx].is_empty();
        self.queues[key_idx].push_back(req);
        // Keep the due-index invariant: every key that may flush holds
        // an entry at or before the cycle its condition first holds —
        // a fresh window opening (front expiry), a filling arrival, or
        // an urgent preemption (both due immediately).
        if was_empty {
            self.due.push(
                arrival.saturating_add(self.cfg.max_wait_cycles),
                SimEventKind::WindowExpiry(key_idx),
            );
        }
        if due_now || self.queues[key_idx].len() >= self.cfg.max_batch {
            self.due.push(arrival, SimEventKind::WindowExpiry(key_idx));
        }
        debug_assert!(self.queued() <= self.cfg.max_queue, "bounded queue invariant");
        true
    }

    /// Ready cycle of a flushed slice: a full batch was triggered by the
    /// arrival that filled it; a partial one by its oldest member's
    /// window expiry, clamped to the last member's arrival (a batch can
    /// never be ready before a member exists — with `max_wait_cycles =
    /// 0` the unclamped expiry *predates* later members).
    fn slice_ready(&self, requests: &[PendingRequest]) -> u64 {
        let last_arrival = requests.last().expect("non-empty batch").arrival;
        if requests.len() == self.cfg.max_batch {
            last_arrival
        } else {
            (requests.first().expect("non-empty batch").arrival + self.cfg.max_wait_cycles)
                .max(last_arrival)
        }
    }

    /// Flush every batch whose condition holds at virtual time `now`:
    /// full (`max_batch` members, ready = the filling arrival), expired
    /// (oldest member waited `max_wait_cycles`, ready = its window expiry
    /// clamped to the last member's arrival), or preemptively urgent
    /// (a window-doomed member's class flushes immediately at `now`,
    /// leaving lower-class members queued). Batches come out in key
    /// order, oldest first.
    ///
    /// The indexed path (default) drains the due-index instead of
    /// scanning every key: entries at or before `now` name the only
    /// keys whose flush condition can hold (the invariant [`offer`]
    /// (Batcher::offer) and the post-flush re-arm maintain), visited in
    /// ascending key order — the same order, batches and cycles as the
    /// full scan.
    pub fn pop_due(&mut self, now: u64) -> Vec<ReadyBatch> {
        let mut out = Vec::new();
        if !self.indexed {
            for key_idx in 0..self.queues.len() {
                self.flush_key_due(key_idx, now, &mut out);
            }
            return out;
        }
        let mut due_keys: BTreeSet<usize> = BTreeSet::new();
        while let Some(ev) = self.due.pop_due(now) {
            if let SimEventKind::WindowExpiry(k) = ev.kind {
                due_keys.insert(k);
            }
        }
        for key_idx in due_keys {
            self.flush_key_due(key_idx, now, &mut out);
            // Re-arm whatever stayed queued (a conservative early entry,
            // or preemption leftovers) at its front's window expiry.
            if let Some(front) = self.queues[key_idx].front() {
                self.due.push(
                    front.arrival.saturating_add(self.cfg.max_wait_cycles),
                    SimEventKind::WindowExpiry(key_idx),
                );
            }
        }
        out
    }

    /// Flush one key's due batches into `out` — the per-key body shared
    /// verbatim by the indexed and full-scan paths.
    fn flush_key_due(&mut self, key_idx: usize, now: u64, out: &mut Vec<ReadyBatch>) {
        if let Some(prio) = self.urgent[key_idx].take() {
            let mut taken = Vec::new();
            let mut kept = VecDeque::new();
            for r in self.queues[key_idx].drain(..) {
                if r.priority >= prio && taken.len() < self.cfg.max_batch {
                    taken.push(r);
                } else {
                    kept.push_back(r);
                }
            }
            self.queues[key_idx] = kept;
            if !taken.is_empty() {
                self.preempt_flushes += 1;
                let batch = ReadyBatch {
                    key_idx,
                    ready: now,
                    requests: taken,
                };
                self.log_flush(
                    &batch,
                    EventKind::FlushPreempt {
                        batch_size: batch.requests.len(),
                    },
                );
                out.push(batch);
            }
        }
        loop {
            let q = &self.queues[key_idx];
            let full = q.len() >= self.cfg.max_batch;
            let expired = q
                .front()
                .map(|r| r.arrival + self.cfg.max_wait_cycles <= now)
                .unwrap_or(false);
            if !full && !expired {
                break;
            }
            let take = q.len().min(self.cfg.max_batch);
            let requests: Vec<PendingRequest> =
                self.queues[key_idx].drain(..take).collect();
            let ready = self.slice_ready(&requests);
            let batch = ReadyBatch {
                key_idx,
                ready,
                requests,
            };
            self.log_flush(&batch, Self::flush_kind(&batch, self.cfg.max_batch));
            out.push(batch);
        }
    }

    /// Flush everything still queued (end of trace), each remaining
    /// group becoming one batch per `max_batch` slice — full slices were
    /// ready when their last member arrived, partial ones at their
    /// oldest member's window expiry (clamped to the last arrival).
    pub fn drain_all(&mut self) -> Vec<ReadyBatch> {
        let mut out = Vec::new();
        for key_idx in 0..self.queues.len() {
            while !self.queues[key_idx].is_empty() {
                let take = self.queues[key_idx].len().min(self.cfg.max_batch);
                let requests: Vec<PendingRequest> =
                    self.queues[key_idx].drain(..take).collect();
                let ready = self.slice_ready(&requests);
                let batch = ReadyBatch {
                    key_idx,
                    ready,
                    requests,
                };
                self.log_flush(&batch, Self::flush_kind(&batch, self.cfg.max_batch));
                out.push(batch);
            }
        }
        out
    }

    /// Flush cause of a non-preemptive batch: full iff it carries
    /// `max_batch` members, otherwise its window expired (or the trace
    /// ended, which drains by window-expiry semantics).
    fn flush_kind(batch: &ReadyBatch, max_batch: usize) -> EventKind {
        if batch.requests.len() == max_batch {
            EventKind::FlushFull {
                batch_size: batch.requests.len(),
            }
        } else {
            EventKind::FlushWindow {
                batch_size: batch.requests.len(),
            }
        }
    }

    /// Split flushed batches that mix deadline-critical members (riding
    /// the full batch is predicted to miss their deadline) with
    /// deferrable ones. The critical half keeps the batch's ready cycle
    /// and dispatches with fewer riders; the deferrable half pays one
    /// extra per-invocation overhead. Batches without a cost estimate,
    /// with fewer than two members, or homogeneous in criticality pass
    /// through untouched (member order preserved).
    pub fn split_critical(&mut self, batches: Vec<ReadyBatch>) -> Vec<ReadyBatch> {
        let mut out = Vec::with_capacity(batches.len());
        for b in batches {
            let Some((base, per_image)) = self.est[b.key_idx] else {
                out.push(b);
                continue;
            };
            if b.requests.len() < 2 {
                out.push(b);
                continue;
            }
            let full_finish = b
                .ready
                .saturating_add(base)
                .saturating_add(per_image.saturating_mul(b.requests.len() as u64));
            let ReadyBatch {
                key_idx,
                ready,
                requests,
            } = b;
            let (critical, deferrable): (Vec<PendingRequest>, Vec<PendingRequest>) = requests
                .into_iter()
                .partition(|r| r.deadline != u64::MAX && full_finish > r.deadline);
            if critical.is_empty() || deferrable.is_empty() {
                let requests = if critical.is_empty() { deferrable } else { critical };
                out.push(ReadyBatch {
                    key_idx,
                    ready,
                    requests,
                });
            } else {
                self.splits += 1;
                out.push(ReadyBatch {
                    key_idx,
                    ready,
                    requests: critical,
                });
                out.push(ReadyBatch {
                    key_idx,
                    ready,
                    requests: deferrable,
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, key_idx: usize, arrival: u64) -> PendingRequest {
        PendingRequest {
            id,
            key_idx,
            arrival,
            priority: 0,
            deadline: u64::MAX,
        }
    }

    fn classed(id: usize, key_idx: usize, arrival: u64, priority: u8, deadline: u64) -> PendingRequest {
        PendingRequest {
            priority,
            deadline,
            ..req(id, key_idx, arrival)
        }
    }

    fn cfg(max_batch: usize, max_wait: u64, max_queue: usize) -> BatcherCfg {
        BatcherCfg {
            max_batch,
            max_wait_cycles: max_wait,
            max_queue,
            ..BatcherCfg::default()
        }
    }

    #[test]
    fn batch_priority_is_the_most_urgent_member() {
        let mut b = Batcher::new(cfg(4, 1000, 16), 1);
        b.offer(req(0, 0, 1));
        b.offer(PendingRequest {
            priority: 2,
            ..req(1, 0, 2)
        });
        let due = b.drain_all();
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].priority(), 2);
    }

    #[test]
    fn full_batch_flushes_at_filling_arrival() {
        let mut b = Batcher::new(cfg(3, 1000, 16), 1);
        b.offer(req(0, 0, 10));
        b.offer(req(1, 0, 20));
        b.offer(req(2, 0, 30));
        let due = b.pop_due(30);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].requests.len(), 3);
        assert_eq!(due[0].ready, 30, "ready when the third request landed");
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn window_expiry_flushes_partial_batch() {
        let mut b = Batcher::new(cfg(8, 1000, 16), 1);
        b.offer(req(0, 0, 100));
        b.offer(req(1, 0, 400));
        assert!(b.pop_due(1099).is_empty(), "window still open");
        let due = b.pop_due(1100);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].requests.len(), 2);
        assert_eq!(due[0].ready, 1100, "oldest member's window expiry");
    }

    #[test]
    fn keys_batch_independently() {
        let mut b = Batcher::new(cfg(2, 1000, 16), 2);
        b.offer(req(0, 0, 10));
        b.offer(req(1, 1, 15));
        b.offer(req(2, 0, 20));
        let due = b.pop_due(20);
        // Key 0 filled (2 members); key 1 still waiting.
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].key_idx, 0);
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn bounded_queue_sheds() {
        let mut b = Batcher::new(cfg(8, 1000, 2), 1);
        assert!(b.offer(req(0, 0, 1)));
        assert!(b.offer(req(1, 0, 2)));
        assert!(!b.offer(req(2, 0, 3)), "third concurrent request is shed");
        assert_eq!(b.shed, 1);
        assert_eq!(b.shed_by_class, [0, 0, 1], "best-effort shed lands in the batch class");
        assert_eq!(b.shed_deadline_by_class, [0, 0, 0], "no deadline was lost");
        assert_eq!(b.queued(), 2);
    }

    #[test]
    fn shed_starts_exactly_at_max_queue() {
        // The bound is inclusive: request `max_queue` is admitted,
        // request `max_queue + 1` is shed, and a flush reopens capacity.
        let mut b = Batcher::new(cfg(8, 1_000_000, 3), 2);
        assert!(b.offer(req(0, 0, 0)));
        assert!(b.offer(req(1, 1, 0)));
        assert!(b.offer(req(2, 0, 0)), "bound counts the whole queue, not one key");
        assert!(!b.offer(req(3, 1, 0)));
        assert_eq!((b.queued(), b.shed), (3, 1));
        // Draining key 0 frees two slots; admissions resume.
        let due = b.drain_all();
        assert_eq!(due.iter().map(|d| d.requests.len()).sum::<usize>(), 3);
        assert!(b.offer(req(4, 0, 10)));
        assert_eq!(b.shed, 1, "shed count is cumulative, not reset by drain");
    }

    #[test]
    fn flush_on_full_precedes_deadline_flush_of_younger_requests() {
        // Key 0 fills (flush-on-full, ready = filling arrival); key 1's
        // lone older request must still flush at its own window expiry,
        // not ride along early. pop_due returns both; ready times order
        // them.
        let mut b = Batcher::new(cfg(2, 1000, 16), 2);
        b.offer(req(0, 1, 5)); // oldest overall, alone on key 1
        b.offer(req(1, 0, 600));
        b.offer(req(2, 0, 900)); // fills key 0
        let due = b.pop_due(1100);
        assert_eq!(due.len(), 2);
        let full = due.iter().find(|d| d.key_idx == 0).unwrap();
        let expired = due.iter().find(|d| d.key_idx == 1).unwrap();
        assert_eq!(full.ready, 900, "full batch ready at the filling arrival");
        assert_eq!(expired.ready, 5 + 1000, "partial batch ready at its window expiry");
        // The full batch became ready before the older request's window
        // closed — downstream ready-time ordering places it first.
        assert!(full.ready < expired.ready);
    }

    #[test]
    fn zero_wait_window_flushes_every_arrival_alone() {
        // max_wait_cycles = 0 degenerates to no batching: each arrival's
        // window has already expired by its own arrival cycle.
        let mut b = Batcher::new(cfg(8, 0, 16), 1);
        b.offer(req(0, 0, 100));
        let due = b.pop_due(100);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].requests.len(), 1);
        assert_eq!(due[0].ready, 100, "zero-wait batch is ready on arrival");
        b.offer(req(1, 0, 100));
        b.offer(req(2, 0, 101));
        // Both pending windows are expired at t=101; they flush as one
        // batch per pop (queue order preserved). The batch cannot be
        // ready before its last member exists: ready clamps to 101, not
        // the oldest member's (already-expired) window at 100.
        let due = b.pop_due(101);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].requests.len(), 2);
        assert_eq!(due[0].ready, 101, "ready clamps to the last member's arrival");
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn expired_ready_never_predates_a_member_arrival() {
        // Regression (ISSUE 4): with a zero-wait window, a two-member
        // batch used to flush at ready = 100 even though its second
        // member only arrives at cycle 101.
        let mut b = Batcher::new(cfg(8, 0, 16), 1);
        b.offer(req(0, 0, 100));
        b.offer(req(1, 0, 101));
        let due = b.pop_due(101);
        assert_eq!(due.len(), 1);
        let batch = &due[0];
        assert!(
            batch.requests.iter().all(|r| r.arrival <= batch.ready),
            "no member may arrive after the batch's ready cycle"
        );
        assert_eq!(batch.ready, 101);
        // drain_all obeys the same clamp.
        let mut b = Batcher::new(cfg(8, 0, 16), 1);
        b.offer(req(0, 0, 100));
        b.offer(req(1, 0, 105));
        let rest = b.drain_all();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].ready, 105);
    }

    #[test]
    fn drain_flushes_leftovers_in_slices() {
        let mut b = Batcher::new(cfg(2, 1000, 16), 1);
        for i in 0..5 {
            b.offer(req(i, 0, i as u64));
        }
        // Two full batches flush on demand; one leftover drains.
        let due = b.pop_due(4);
        assert_eq!(due.len(), 2);
        let rest = b.drain_all();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].requests.len(), 1);
        assert_eq!(rest[0].ready, 4 + 1000);
        assert_eq!(b.queued(), 0);
    }

    // ------------------------------------------------------------------
    // Class-aware admission
    // ------------------------------------------------------------------

    #[test]
    fn class_admission_evicts_batch_class_before_interactive() {
        let mut b = Batcher::new(
            BatcherCfg {
                admission: AdmissionKind::ClassAware,
                ..cfg(8, 1_000_000, 2)
            },
            1,
        );
        assert!(b.offer(classed(0, 0, 0, 0, u64::MAX))); // batch class
        assert!(b.offer(classed(1, 0, 0, 0, u64::MAX))); // batch class
        // Interactive arrival at a full queue evicts the youngest
        // batch-class request instead of being shed itself.
        assert!(b.offer(classed(2, 0, 1, 2, 5_000)));
        assert_eq!(b.shed, 1);
        assert_eq!(b.shed_by_class, [0, 0, 1], "a batch-class victim was shed");
        assert_eq!(b.queued(), 2);
        let due = b.drain_all();
        let ids: Vec<usize> = due.iter().flat_map(|d| d.requests.iter().map(|r| r.id)).collect();
        assert!(ids.contains(&2), "the interactive request survived");
        assert!(!ids.contains(&1), "the youngest batch-class request was evicted");
    }

    #[test]
    fn class_admission_sheds_incoming_when_no_lower_class_exists() {
        let mut b = Batcher::new(
            BatcherCfg {
                admission: AdmissionKind::ClassAware,
                ..cfg(8, 1_000_000, 2)
            },
            1,
        );
        assert!(b.offer(classed(0, 0, 0, 2, 100)));
        assert!(b.offer(classed(1, 0, 0, 2, 100)));
        // Same-class arrival cannot evict: eviction requires a victim
        // strictly below the arrival's priority.
        assert!(!b.offer(classed(2, 0, 0, 2, 100)));
        assert_eq!(b.shed_by_class, [1, 0, 0]);
        assert_eq!(
            b.shed_deadline_by_class,
            [1, 0, 0],
            "the shed interactive request carried a deadline"
        );
        // And a batch-class arrival at a full interactive queue sheds too.
        assert!(!b.offer(classed(3, 0, 0, 0, u64::MAX)));
        assert_eq!(b.shed_by_class, [1, 0, 1]);
    }

    #[test]
    fn fifo_admission_sheds_incoming_regardless_of_class() {
        let mut b = Batcher::new(cfg(8, 1_000_000, 2), 1);
        assert!(b.offer(classed(0, 0, 0, 0, u64::MAX)));
        assert!(b.offer(classed(1, 0, 0, 0, u64::MAX)));
        assert!(!b.offer(classed(2, 0, 1, 2, 5_000)), "FIFO sheds the interactive arrival");
        assert_eq!(b.shed_by_class, [1, 0, 0]);
        assert_eq!(b.shed_deadline_by_class, [1, 0, 0]);
    }

    // ------------------------------------------------------------------
    // Preemptive flush + batch splitting
    // ------------------------------------------------------------------

    #[test]
    fn preemptive_flush_pulls_doomed_interactive_ahead_of_window() {
        let mut b = Batcher::new(
            BatcherCfg {
                preempt: true,
                ..cfg(8, 10_000, 16)
            },
            1,
        );
        b.set_est_cost(0, 1_000, 500);
        // A batch-class request opens the window at t=0.
        b.offer(classed(0, 0, 0, 0, u64::MAX));
        // Interactive arrival at t=100 whose deadline (5_000) dies before
        // the window expiry (10_000): flush it now, leaving the
        // batch-class member to wait out its window.
        b.offer(classed(1, 0, 100, 2, 5_000));
        let due = b.pop_due(100);
        assert_eq!(due.len(), 1, "only the urgent class flushes");
        assert_eq!(due[0].ready, 100, "preemptive flush is ready at the triggering arrival");
        assert_eq!(due[0].requests.len(), 1);
        assert_eq!(due[0].requests[0].id, 1);
        assert_eq!(b.preempt_flushes, 1);
        assert_eq!(b.queued(), 1, "the batch-class member stays queued");
        // The leftover still flushes at its own window expiry.
        let rest = b.pop_due(10_000);
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].requests[0].id, 0);
    }

    #[test]
    fn preemptive_flush_takes_same_class_partners() {
        let mut b = Batcher::new(
            BatcherCfg {
                preempt: true,
                ..cfg(8, 10_000, 16)
            },
            1,
        );
        b.set_est_cost(0, 1_000, 500);
        b.offer(classed(0, 0, 0, 2, u64::MAX - 1)); // interactive, relaxed deadline
        b.offer(classed(1, 0, 0, 0, u64::MAX)); // batch class
        b.offer(classed(2, 0, 50, 2, 4_000)); // doomed interactive
        let due = b.pop_due(50);
        assert_eq!(due.len(), 1);
        let ids: Vec<usize> = due[0].requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2], "same-class partners ride the preemptive flush in order");
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn no_preemption_without_estimate_or_flag() {
        // Without a cost estimate the doom test is inert.
        let mut b = Batcher::new(
            BatcherCfg {
                preempt: true,
                ..cfg(8, 10_000, 16)
            },
            1,
        );
        b.offer(classed(0, 0, 0, 2, 1));
        assert!(b.pop_due(0).is_empty(), "no estimate, no preemptive flush");
        // With the flag off the estimate alone does nothing.
        let mut b = Batcher::new(cfg(8, 10_000, 16), 1);
        b.set_est_cost(0, 1_000, 500);
        b.offer(classed(0, 0, 0, 2, 1));
        assert!(b.pop_due(0).is_empty(), "preemption is opt-in");
    }

    #[test]
    fn split_critical_divides_mixed_batches_only() {
        let mut b = Batcher::new(
            BatcherCfg {
                preempt: true,
                ..cfg(8, 1_000, 16)
            },
            1,
        );
        b.set_est_cost(0, 1_000, 500);
        // ready 0 + base 1000 + 3*500 = 2500 predicted full-batch finish.
        let batch = ReadyBatch {
            key_idx: 0,
            ready: 0,
            requests: vec![
                classed(0, 0, 0, 0, u64::MAX),   // deferrable
                classed(1, 0, 0, 2, 2_000),      // critical (2000 < 2500)
                classed(2, 0, 0, 1, 10_000),     // deferrable (deadline safe)
            ],
        };
        let out = b.split_critical(vec![batch]);
        assert_eq!(out.len(), 2);
        assert_eq!(b.splits, 1);
        assert_eq!(out[0].requests.len(), 1, "critical half leads");
        assert_eq!(out[0].requests[0].id, 1);
        assert_eq!(out[1].requests.len(), 2, "deferrable half keeps member order");
        assert_eq!(out[1].requests[0].id, 0);
        assert_eq!(out[0].ready, out[1].ready, "both halves keep the flush cycle");

        // Homogeneous batches pass through untouched.
        let safe = ReadyBatch {
            key_idx: 0,
            ready: 0,
            requests: vec![classed(3, 0, 0, 0, u64::MAX), classed(4, 0, 0, 0, u64::MAX)],
        };
        let out = b.split_critical(vec![safe]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].requests.len(), 2);
        assert_eq!(b.splits, 1, "no additional split");
    }

    #[test]
    fn event_log_is_gated_and_covers_admission_and_flushes() {
        let mut b = Batcher::new(cfg(2, 1000, 2), 1);
        b.offer(req(0, 0, 1));
        assert!(b.drain_events().is_empty(), "logging is off by default");
        b.set_record(true);
        b.offer(req(1, 0, 2)); // fills the batch
        assert!(!b.offer(req(2, 0, 3)), "queue full: shed");
        let due = b.pop_due(3);
        assert_eq!(due.len(), 1);
        let kinds: Vec<&str> = b.drain_events().iter().map(|e| e.kind.name()).collect();
        assert_eq!(kinds, vec!["Admit", "Shed", "FlushFull"]);
        assert!(b.drain_events().is_empty(), "drain empties the log");
        // Window-expiry drain logs FlushWindow; class-aware eviction logs
        // Evict with the victim's identity.
        let mut b = Batcher::new(
            BatcherCfg {
                admission: AdmissionKind::ClassAware,
                ..cfg(8, 1_000_000, 1)
            },
            1,
        );
        b.set_record(true);
        b.offer(classed(0, 0, 0, 0, u64::MAX));
        b.offer(classed(1, 0, 5, 2, 9_999)); // evicts id 0
        let _ = b.drain_all();
        let events = b.drain_events();
        let kinds: Vec<&str> = events.iter().map(|e| e.kind.name()).collect();
        assert_eq!(kinds, vec!["Admit", "Evict", "Admit", "FlushWindow"]);
        assert_eq!(events[1].id, 0, "Evict names the victim");
        assert_eq!(events[1].cycles, 5, "Evict is stamped at the evicting arrival");
        assert_eq!(
            events[1].kind,
            EventKind::Evict { had_deadline: false }
        );
    }

    #[test]
    fn class_index_maps_priorities() {
        assert_eq!(class_index(2), 0, "interactive");
        assert_eq!(class_index(1), 1, "standard");
        assert_eq!(class_index(0), 2, "batch");
        assert_eq!(class_index(9), 0, "priorities clamp to interactive");
    }

    // ------------------------------------------------------------------
    // Event-loop due-index (indexed pop_due)
    // ------------------------------------------------------------------

    #[test]
    fn indexed_pop_due_matches_the_full_key_scan() {
        // The same offer/pop sequence driven through the due-index and
        // the legacy full-key scan: identical batches, ready cycles and
        // member order at every step — the batcher-level half of the
        // event-loop equivalence pin. The sequence exercises full
        // flushes, window expiries, urgent preemption and class-aware
        // eviction.
        let mk = || {
            Batcher::new(
                BatcherCfg {
                    admission: AdmissionKind::ClassAware,
                    preempt: true,
                    ..cfg(3, 1_000, 4)
                },
                3,
            )
        };
        let mut fast = mk();
        let mut scan = mk();
        scan.set_indexed(false);
        fast.set_est_cost(1, 500, 200);
        scan.set_est_cost(1, 500, 200);
        let offers = [
            classed(0, 0, 10, 0, u64::MAX),
            classed(1, 1, 20, 0, u64::MAX),
            classed(2, 0, 30, 1, 50_000),
            classed(3, 1, 40, 2, 900), // window-doomed on key 1: urgent
            classed(4, 0, 45, 0, u64::MAX), // fills key 0 (max_batch 3)
            classed(5, 2, 60, 2, 70_000),
            classed(6, 2, 70, 0, u64::MAX),
            classed(7, 2, 80, 0, u64::MAX),
            classed(8, 0, 1_500, 1, 90_000), // past earlier window expiries
        ];
        let sig = |b: &[ReadyBatch]| -> Vec<(usize, u64, Vec<usize>)> {
            b.iter()
                .map(|x| (x.key_idx, x.ready, x.requests.iter().map(|r| r.id).collect()))
                .collect()
        };
        for r in offers {
            let now = r.arrival;
            assert_eq!(sig(&fast.pop_due(now)), sig(&scan.pop_due(now)));
            assert_eq!(fast.offer(r.clone()), scan.offer(r));
            assert_eq!(sig(&fast.pop_due(now)), sig(&scan.pop_due(now)));
        }
        assert_eq!(sig(&fast.pop_due(5_000)), sig(&scan.pop_due(5_000)));
        assert_eq!(sig(&fast.drain_all()), sig(&scan.drain_all()));
        assert_eq!((fast.queued(), scan.queued()), (0, 0));
        assert_eq!(
            (fast.shed, fast.shed_by_class, fast.preempt_flushes, fast.splits),
            (scan.shed, scan.shed_by_class, scan.preempt_flushes, scan.splits)
        );
    }

    #[test]
    fn due_index_survives_front_eviction() {
        // Class-aware eviction can remove a queue's oldest member, so
        // the index entry armed for the old front goes conservative
        // (fires early). The early firing must flush nothing and re-arm
        // at the surviving front's window expiry — which must then
        // flush exactly on time.
        let mut b = Batcher::new(
            BatcherCfg {
                admission: AdmissionKind::ClassAware,
                ..cfg(8, 1_000, 2)
            },
            1,
        );
        assert!(b.offer(classed(0, 0, 100, 0, u64::MAX)));
        assert!(b.offer(classed(1, 0, 400, 1, u64::MAX)));
        // Full queue: the interactive arrival evicts id 0 — the front.
        assert!(b.offer(classed(2, 0, 500, 2, u64::MAX)));
        assert_eq!(b.shed, 1);
        assert!(
            b.pop_due(1_100).is_empty(),
            "the evicted front's entry is conservative: nothing is due"
        );
        assert!(b.pop_due(1_399).is_empty(), "survivor's window still open");
        let due = b.pop_due(1_400);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].ready, 1_400, "flushes at the surviving front's expiry");
        let ids: Vec<usize> = due[0].requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2]);
    }
}
