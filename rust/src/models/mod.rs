//! Model zoo: layer geometry shared with the Layer-2 JAX definitions.
//!
//! The constructors here mirror `python/compile/model.py` exactly; at run
//! time the authoritative geometry is loaded from `artifacts/manifest.json`
//! (written by the AOT path) and cross-checked against these constructors
//! in tests, so drift between the layers is caught immediately.

use crate::util::json::{Json, JsonError};

/// Layer kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    DwConv,
    Dense,
}

impl LayerKind {
    pub fn parse(s: &str) -> Option<LayerKind> {
        match s {
            "conv" => Some(LayerKind::Conv),
            "dwconv" => Some(LayerKind::DwConv),
            "dense" => Some(LayerKind::Dense),
            _ => None,
        }
    }
}

/// One quantizable layer (geometry mirror of the Python `LayerSpec`).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSpec {
    pub name: String,
    pub kind: LayerKind,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub out_h: usize,
    pub out_w: usize,
    pub pool_after: bool,
    pub gap_before: bool,
    pub w_offset: usize,
    pub w_size: usize,
    pub b_offset: usize,
    pub b_size: usize,
    pub macs: u64,
}

impl LayerSpec {
    /// MAC count (recomputed; must agree with the manifest).
    pub fn compute_macs(&self) -> u64 {
        match self.kind {
            LayerKind::Conv => {
                (self.out_h * self.out_w * self.k * self.k * self.cin * self.cout) as u64
            }
            LayerKind::DwConv => (self.out_h * self.out_w * self.k * self.k * self.cout) as u64,
            LayerKind::Dense => (self.cin * self.cout) as u64,
        }
    }

    /// Activation output element count (pre-pool).
    pub fn out_elems(&self) -> usize {
        match self.kind {
            LayerKind::Dense => self.cout,
            _ => self.out_h * self.out_w * self.cout,
        }
    }

    /// Activation input element count.
    pub fn in_elems(&self) -> usize {
        match self.kind {
            LayerKind::Dense => self.cin,
            _ => self.in_h * self.in_w * self.cin,
        }
    }

    /// Weight bytes when stored packed at `bits` per weight (sub-byte
    /// flash packing, the flash-size lever of Table I).
    pub fn weight_bytes_at(&self, bits: u8) -> usize {
        (self.w_size * bits as usize).div_ceil(8) + self.b_size * 4 // biases stay int32
    }
}

/// A model family entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDesc {
    pub name: String,
    pub input_hw: usize,
    pub input_c: usize,
    pub num_classes: usize,
    pub layers: Vec<LayerSpec>,
    pub param_count: usize,
}

impl ModelDesc {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// Total flash bytes for the weights under a bit configuration.
    pub fn weight_flash_bytes(&self, wbits: &[u8]) -> usize {
        self.layers
            .iter()
            .zip(wbits)
            .map(|(l, &b)| l.weight_bytes_at(b))
            .sum()
    }
}

fn finalize(name: &str, input_hw: usize, input_c: usize, num_classes: usize,
            mut layers: Vec<LayerSpec>) -> ModelDesc {
    let mut off = 0usize;
    for l in &mut layers {
        l.w_offset = off;
        l.w_size = match l.kind {
            LayerKind::Conv => l.k * l.k * l.cin * l.cout,
            LayerKind::DwConv => l.k * l.k * l.cout,
            LayerKind::Dense => l.cin * l.cout,
        };
        off += l.w_size;
        l.b_offset = off;
        l.b_size = l.cout;
        off += l.b_size;
        l.macs = l.compute_macs();
    }
    ModelDesc {
        name: name.to_string(),
        input_hw,
        input_c,
        num_classes,
        layers,
        param_count: off,
    }
}

fn conv(name: &str, cin: usize, cout: usize, k: usize, h: usize, pool: bool) -> LayerSpec {
    LayerSpec {
        name: name.into(),
        kind: LayerKind::Conv,
        cin,
        cout,
        k,
        stride: 1,
        in_h: h,
        in_w: h,
        out_h: h,
        out_w: h,
        pool_after: pool,
        gap_before: false,
        w_offset: 0,
        w_size: 0,
        b_offset: 0,
        b_size: 0,
        macs: 0,
    }
}

fn dwconv(name: &str, c: usize, h: usize) -> LayerSpec {
    LayerSpec {
        kind: LayerKind::DwConv,
        cin: c,
        cout: c,
        k: 3,
        ..conv(name, c, c, 3, h, false)
    }
}

fn dense(name: &str, cin: usize, cout: usize, gap: bool) -> LayerSpec {
    LayerSpec {
        name: name.into(),
        kind: LayerKind::Dense,
        cin,
        cout,
        k: 1,
        stride: 1,
        in_h: 1,
        in_w: 1,
        out_h: 1,
        out_w: 1,
        pool_after: false,
        gap_before: gap,
        w_offset: 0,
        w_size: 0,
        b_offset: 0,
        b_size: 0,
        macs: 0,
    }
}

/// VGG-Tiny (Table I row 1) — mirrors `model.py::vgg_tiny`.
pub fn vgg_tiny(num_classes: usize, hw: usize) -> ModelDesc {
    let h = hw;
    let mut layers = vec![
        conv("conv1", 3, 16, 3, h, false),
        conv("conv2", 16, 16, 3, h, true),
    ];
    let h2 = h / 2;
    layers.push(conv("conv3", 16, 32, 3, h2, false));
    layers.push(conv("conv4", 32, 32, 3, h2, true));
    let h4 = h2 / 2;
    layers.push(conv("conv5", 32, 64, 3, h4, true));
    let h8 = h4 / 2;
    layers.push(dense("fc", h8 * h8 * 64, num_classes, false));
    finalize("vgg_tiny", hw, 3, num_classes, layers)
}

/// MobileNet-Tiny (Table I row 2) — mirrors `model.py::mobilenet_tiny`.
pub fn mobilenet_tiny(num_classes: usize, hw: usize) -> ModelDesc {
    let h = hw;
    let mut layers = vec![
        conv("conv1", 3, 16, 3, h, false),
        dwconv("dw1", 16, h),
        conv("pw1", 16, 32, 1, h, true),
    ];
    let h2 = h / 2;
    layers.push(dwconv("dw2", 32, h2));
    layers.push(conv("pw2", 32, 64, 1, h2, true));
    let h4 = h2 / 2;
    layers.push(dwconv("dw3", 64, h4));
    layers.push(conv("pw3", 64, 64, 1, h4, false));
    layers.push(dense("fc", 64, num_classes, true));
    finalize("mobilenet_tiny", hw, 3, num_classes, layers)
}

/// Look up a backbone constructor by name (num_classes per Table I).
pub fn by_name(name: &str) -> Option<ModelDesc> {
    match name {
        "vgg_tiny" => Some(vgg_tiny(10, 16)),
        "mobilenet_tiny" => Some(mobilenet_tiny(2, 16)),
        _ => None,
    }
}

/// Parse a backbone entry of `artifacts/manifest.json`.
pub fn from_manifest(name: &str, entry: &Json) -> Result<ModelDesc, JsonError> {
    let layers_json = entry
        .req("layers")?
        .as_arr()
        .ok_or_else(|| JsonError("layers not an array".into()))?;
    let mut layers = Vec::with_capacity(layers_json.len());
    for lj in layers_json {
        let get_usize = |k: &str| -> Result<usize, JsonError> {
            lj.req(k)?
                .as_usize()
                .ok_or_else(|| JsonError(format!("{k} not a number")))
        };
        let kind_s = lj
            .req("kind")?
            .as_str()
            .ok_or_else(|| JsonError("kind not a string".into()))?;
        layers.push(LayerSpec {
            name: lj
                .req("name")?
                .as_str()
                .ok_or_else(|| JsonError("name not a string".into()))?
                .to_string(),
            kind: LayerKind::parse(kind_s)
                .ok_or_else(|| JsonError(format!("unknown kind {kind_s}")))?,
            cin: get_usize("cin")?,
            cout: get_usize("cout")?,
            k: get_usize("k")?,
            stride: get_usize("stride")?,
            in_h: get_usize("in_h")?,
            in_w: get_usize("in_w")?,
            out_h: get_usize("out_h")?,
            out_w: get_usize("out_w")?,
            pool_after: lj.req("pool_after")?.as_bool().unwrap_or(false),
            gap_before: lj.req("gap_before")?.as_bool().unwrap_or(false),
            w_offset: get_usize("w_offset")?,
            w_size: get_usize("w_size")?,
            b_offset: get_usize("b_offset")?,
            b_size: get_usize("b_size")?,
            macs: get_usize("macs")? as u64,
        });
    }
    Ok(ModelDesc {
        name: name.to_string(),
        input_hw: entry.req("input_hw")?.as_usize().unwrap_or(16),
        input_c: entry.req("input_c")?.as_usize().unwrap_or(3),
        num_classes: entry.req("num_classes")?.as_usize().unwrap_or(10),
        layers,
        param_count: entry.req("param_count")?.as_usize().unwrap_or(0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg_tiny_geometry() {
        let m = vgg_tiny(10, 16);
        assert_eq!(m.num_layers(), 6);
        assert_eq!(m.layers[0].cin, 3);
        assert_eq!(m.layers[5].kind, LayerKind::Dense);
        assert_eq!(m.layers[5].cin, 2 * 2 * 64);
        // Param count must match the Python side (checked again in the
        // integration test against the manifest): 37722.
        assert_eq!(m.param_count, 37_722);
    }

    #[test]
    fn mobilenet_tiny_geometry() {
        let m = mobilenet_tiny(2, 16);
        assert_eq!(m.num_layers(), 8);
        assert_eq!(m.param_count, 8_514);
        assert!(m.layers[7].gap_before);
    }

    #[test]
    fn offsets_contiguous() {
        for m in [vgg_tiny(10, 16), mobilenet_tiny(2, 16)] {
            let mut off = 0;
            for l in &m.layers {
                assert_eq!(l.w_offset, off);
                off += l.w_size;
                assert_eq!(l.b_offset, off);
                off += l.b_size;
            }
            assert_eq!(m.param_count, off);
        }
    }

    #[test]
    fn macs_match_recompute() {
        for m in [vgg_tiny(10, 16), mobilenet_tiny(2, 16)] {
            for l in &m.layers {
                assert_eq!(l.macs, l.compute_macs());
            }
            assert!(m.total_macs() > 0);
        }
    }

    #[test]
    fn sub_byte_weight_bytes() {
        let m = vgg_tiny(10, 16);
        let l = &m.layers[2]; // conv3: 16->32 3x3 = 4608 weights
        assert_eq!(l.weight_bytes_at(8), 4608 + 32 * 4);
        assert_eq!(l.weight_bytes_at(4), 2304 + 32 * 4);
        assert_eq!(l.weight_bytes_at(2), 1152 + 32 * 4);
    }

    #[test]
    fn flash_scales_with_bits() {
        let m = vgg_tiny(10, 16);
        let f8 = m.weight_flash_bytes(&vec![8; 6]);
        let f4 = m.weight_flash_bytes(&vec![4; 6]);
        assert!(f4 < f8);
    }
}
