//! Serving-throughput trajectory bench.
//!
//! Two protocols in one run:
//!
//! 1. **Canonical replay** (unchanged since PR 1): the mixed-fleet
//!    scenario (vgg_tiny on RP-SLBC + mobilenet_tiny on int8 TinyEngine,
//!    320 requests, 4 × STM32F746, round-robin) plus a no-batching
//!    replay quantifying the dynamic-batching win — the long-running
//!    trend line.
//! 2. **Scheduler × fleet matrix** (scheduler-refactor PR, energy rows
//!    added with the `Target` layer): the same tenant pair under a
//!    Zipf-skewed, deadline-classed trace, replayed over an all-M7 and
//!    an m7:2,m4:2 fleet with each placement policy — now including
//!    `energy-aware`. Emits one JSON `rows` array (throughput, p95,
//!    deadline misses, total joules and joules/inference per cell) plus
//!    an `energy_rows` array (per device-class joules for each hetero
//!    cell), and asserts (a) the SLO-aware policy strictly reduces
//!    deadline misses vs round-robin on the heterogeneous fleet, and
//!    (b) the energy-aware policy strictly reduces total joules vs
//!    SLO-aware there without increasing interactive-class SLO misses.
//! 3. **Overload matrix** (overload-resilience PR): a bursty trace
//!    (32-deep synchronized arrival spikes) against a tightly bounded
//!    queue on the m7:2,m4:2 fleet, replayed under FIFO shedding and
//!    under class-aware admission (± preemption + work stealing). Emits
//!    an `overload` JSON array (shed-inclusive per-class misses,
//!    preempt/split/migration counters) and asserts class-aware
//!    admission + preemption strictly cut interactive-class misses vs
//!    FIFO shedding.
//! 4. **Churn matrix** (fault-injection PR): the overload stack under a
//!    seeded 10%-churn fleet-event stream (join/leave/crash/throttle/
//!    drain), with and without crash re-admission. Emits a `churn` JSON
//!    array and asserts bounded SLO degradation: interactive misses
//!    under churn + re-admission stay within 10 percentage points of
//!    the no-churn baseline, and re-admission strictly beats naive
//!    drop-on-crash.
//! 5. **Event-loop replay speed** (event-driven serve-core PR): the
//!    skewed deadline trace on an m7:8,m4:8 fleet, replayed by the
//!    event-heap core (probe counters, indexed scheduling, arena
//!    requests) and by the `legacy_loop` scan core (per-image
//!    inference, linear next-wake/flush scans). The legacy cell
//!    replays a shorter prefix of the same arrival process — both
//!    sides report requests per second of host wall time, so the
//!    rates normalize — and the acceptance is a >=2x replay-rate
//!    speedup, recorded in the JSON line as `event_loop_speedup`.
//!
//! Regenerate with `cargo bench --bench serve_throughput`.

use std::collections::BTreeMap;

use mcu_mixq::ops::Method;
use mcu_mixq::serve::{
    self, AdmissionKind, BatcherCfg, DeviceCfg, SchedulerKind, ServeCfg, ServeReport, TraceCfg,
    Workload,
};
use mcu_mixq::util::bench::Bench;
use mcu_mixq::util::json::Json;

fn workloads() -> Vec<Workload> {
    vec![
        Workload::synth("vgg_tiny", Method::RpSlbc, 4, 101).unwrap(),
        Workload::synth("mobilenet_tiny", Method::TinyEngine, 8, 102).unwrap(),
    ]
}

fn main() -> mcu_mixq::Result<()> {
    let requests = std::env::var("MCU_MIXQ_SERVE_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(320usize);
    let ws = workloads();
    // ~3 ms mean offered gap: enough pressure that batching matters, not
    // enough to saturate four devices.
    let trace = serve::synth_trace(&TraceCfg::new(requests, 648_000, 42), ws.len());
    let cfg = ServeCfg::default();

    println!(
        "serve_throughput — {} requests, {} devices, mixed fleet\n",
        requests,
        cfg.fleet.len()
    );
    let report = serve::run_trace(&ws, &trace, &cfg)?;
    println!("{}", report.render());

    // Same trace with batching disabled (batch = 1, no wait window).
    let solo_cfg = ServeCfg {
        batcher: BatcherCfg {
            max_batch: 1,
            max_wait_cycles: 1,
            max_queue: cfg.batcher.max_queue,
            ..BatcherCfg::default()
        },
        ..cfg.clone()
    };
    let solo = serve::run_trace(&ws, &trace, &solo_cfg)?;
    let batch_speedup = solo.makespan_cycles as f64 / report.makespan_cycles as f64;
    println!(
        "dynamic batching: makespan {} vs unbatched {} cycles ({batch_speedup:.3}x)\n",
        report.makespan_cycles, solo.makespan_cycles
    );

    // ------------------------------------------------------------------
    // Scheduler × fleet matrix under deadline pressure: Zipf-skewed
    // tenants, 60% interactive / 25% standard / 15% batch classes, and a
    // tighter offered gap so queueing actually threatens deadlines.
    // ------------------------------------------------------------------
    let slo_trace = serve::synth_trace(
        &TraceCfg::new(requests, 432_000, 43)
            .with_skew(1.0)
            .with_slo([0.60, 0.25, 0.15]),
        ws.len(),
    );
    let fleets: [(&str, Vec<DeviceCfg>); 2] = [
        ("m7:4", vec![DeviceCfg::stm32f746(); 4]),
        (
            "m7:2,m4:2",
            vec![
                DeviceCfg::stm32f746(),
                DeviceCfg::stm32f746(),
                DeviceCfg::stm32f446(),
                DeviceCfg::stm32f446(),
            ],
        ),
    ];
    let mut rows: Vec<Json> = Vec::new();
    let mut energy_rows: Vec<Json> = Vec::new();
    let mut misses: BTreeMap<(String, &'static str), u64> = BTreeMap::new();
    let mut interactive: BTreeMap<(String, &'static str), u64> = BTreeMap::new();
    let mut joules: BTreeMap<(String, &'static str), f64> = BTreeMap::new();
    println!("scheduler x fleet matrix (skewed deadline trace):");
    for (fleet_name, fleet) in &fleets {
        for kind in SchedulerKind::ALL {
            let cell_cfg = ServeCfg {
                fleet: fleet.clone(),
                scheduler: kind,
                ..ServeCfg::default()
            };
            let rep: ServeReport = serve::run_trace(&ws, &slo_trace, &cell_cfg)?;
            println!(
                "  fleet {:>9}  sched {:>12}  completed {:>3}  throughput {:>7.1} rps  p95 {:>7.2} ms  deadline misses {:>3}  energy {:>8.3} mJ",
                fleet_name,
                kind.name(),
                rep.completed,
                rep.throughput_rps,
                rep.latency.p95_ms,
                rep.deadline_misses,
                rep.total_joules * 1e3
            );
            misses.insert((fleet_name.to_string(), kind.name()), rep.deadline_misses);
            interactive.insert((fleet_name.to_string(), kind.name()), rep.class_misses(0));
            joules.insert((fleet_name.to_string(), kind.name()), rep.total_joules);
            let mut row = BTreeMap::new();
            row.insert("fleet".into(), Json::Str(fleet_name.to_string()));
            row.insert("sched".into(), Json::Str(kind.name().into()));
            row.insert("completed".into(), Json::Num(rep.completed as f64));
            row.insert("throughput_rps".into(), Json::Num(rep.throughput_rps));
            row.insert("p95_ms".into(), Json::Num(rep.latency.p95_ms));
            row.insert(
                "deadline_misses".into(),
                Json::Num(rep.deadline_misses as f64),
            );
            row.insert(
                "interactive_misses".into(),
                Json::Num(rep.class_misses(0) as f64),
            );
            row.insert(
                "makespan_cycles".into(),
                Json::Num(rep.makespan_cycles as f64),
            );
            row.insert("total_joules".into(), Json::Num(rep.total_joules));
            row.insert(
                "joules_per_inference".into(),
                Json::Num(rep.joules_per_inference()),
            );
            rows.push(Json::Obj(row));

            // Per device-class energy rows for the heterogeneous fleet:
            // where each policy actually spends its joules.
            if fleet_name == &"m7:2,m4:2" {
                let mut by_class: BTreeMap<String, (f64, u64)> = BTreeMap::new();
                for d in &rep.per_device {
                    let e = by_class.entry(d.class.clone()).or_insert((0.0, 0));
                    e.0 += d.joules;
                    e.1 += d.images;
                }
                for (class, (j, images)) in by_class {
                    println!(
                        "      class {:>3}  sched {:>12}  images {:>4}  energy {:>8.3} mJ",
                        class,
                        kind.name(),
                        images,
                        j * 1e3
                    );
                    let mut er = BTreeMap::new();
                    er.insert("fleet".into(), Json::Str(fleet_name.to_string()));
                    er.insert("sched".into(), Json::Str(kind.name().into()));
                    er.insert("class".into(), Json::Str(class));
                    er.insert("joules".into(), Json::Num(j));
                    er.insert("images".into(), Json::Num(images as f64));
                    er.insert(
                        "joules_per_inference".into(),
                        Json::Num(if images == 0 { 0.0 } else { j / images as f64 }),
                    );
                    energy_rows.push(Json::Obj(er));
                }
            }
        }
    }
    println!();

    // ------------------------------------------------------------------
    // Overload matrix: 32-deep synchronized arrival bursts against a
    // queue bounded at 8 on the heterogeneous fleet. FIFO shedding
    // drops whatever arrives late — including interactive deadlines —
    // while class-aware admission evicts best-effort work first, and
    // preemption + stealing keep the surviving interactive requests
    // ahead of their deadlines.
    // ------------------------------------------------------------------
    let burst_trace = serve::synth_trace(
        &TraceCfg::new(requests, 432_000, 44)
            .with_skew(1.0)
            .with_slo([0.5, 0.2, 0.3])
            .with_burst(64, 32),
        ws.len(),
    );
    let overload_fleet = vec![
        DeviceCfg::stm32f746(),
        DeviceCfg::stm32f746(),
        DeviceCfg::stm32f446(),
        DeviceCfg::stm32f446(),
    ];
    let overload_cells: [(&str, AdmissionKind, bool, bool); 3] = [
        ("fifo", AdmissionKind::Fifo, false, false),
        ("class", AdmissionKind::ClassAware, false, false),
        ("class+preempt+steal", AdmissionKind::ClassAware, true, true),
    ];
    let mut overload_rows: Vec<Json> = Vec::new();
    let mut interactive_misses: BTreeMap<&'static str, u64> = BTreeMap::new();
    println!("overload matrix (m7:2,m4:2, burst trace, queue<=8):");
    for (label, admission, preempt, steal) in overload_cells {
        let cell_cfg = ServeCfg {
            fleet: overload_fleet.clone(),
            scheduler: SchedulerKind::SloAware,
            batcher: BatcherCfg {
                max_batch: 16,
                max_wait_cycles: 432_000,
                max_queue: 8,
                admission,
                preempt,
            },
            steal,
            ..ServeCfg::default()
        };
        let rep = serve::run_trace(&ws, &burst_trace, &cell_cfg)?;
        assert_eq!(
            rep.completed as u64 + rep.rejected_queue + rep.rejected_sram,
            burst_trace.len() as u64,
            "overload cell `{label}` must conserve requests"
        );
        println!(
            "  {:>19}  completed {:>3}  shed int/std/batch {:>3}/{:>3}/{:>3}  interactive misses {:>3}  preempt {:>3}  splits {:>3}  migrations {:>3}",
            label,
            rep.completed,
            rep.shed_by_class[0],
            rep.shed_by_class[1],
            rep.shed_by_class[2],
            rep.class_misses(0),
            rep.preempt_flushes,
            rep.batch_splits,
            rep.migrations
        );
        interactive_misses.insert(label, rep.class_misses(0));
        let mut row = BTreeMap::new();
        row.insert("admission".into(), Json::Str(label.into()));
        row.insert("steal".into(), Json::Num(if steal { 1.0 } else { 0.0 }));
        row.insert("preempt".into(), Json::Num(if preempt { 1.0 } else { 0.0 }));
        row.insert("completed".into(), Json::Num(rep.completed as f64));
        row.insert("shed_interactive".into(), Json::Num(rep.shed_by_class[0] as f64));
        row.insert("shed_standard".into(), Json::Num(rep.shed_by_class[1] as f64));
        row.insert("shed_batch".into(), Json::Num(rep.shed_by_class[2] as f64));
        row.insert(
            "interactive_misses".into(),
            Json::Num(rep.class_misses(0) as f64),
        );
        row.insert("total_misses".into(), Json::Num(rep.total_misses() as f64));
        row.insert("preempt_flushes".into(), Json::Num(rep.preempt_flushes as f64));
        row.insert("batch_splits".into(), Json::Num(rep.batch_splits as f64));
        row.insert("migrations".into(), Json::Num(rep.migrations as f64));
        row.insert("p95_ms".into(), Json::Num(rep.latency.p95_ms));
        row.insert("throughput_rps".into(), Json::Num(rep.throughput_rps));
        overload_rows.push(Json::Obj(row));
    }
    println!();

    // ------------------------------------------------------------------
    // Churn matrix (fault-injection PR): the overload stack (class-aware
    // admission + preemption + stealing) replayed with a 10%-churn
    // fleet-event stream — devices join, leave, crash, throttle and
    // drain mid-trace. Three cells: the no-churn baseline, churn with
    // crash re-admission (the recovery path), and churn with naive
    // drop-on-crash (`readmit: false`). Asserts the bounded-degradation
    // acceptance: interactive misses under churn+re-admission stay
    // within 10 percentage points of the no-churn baseline, and the
    // re-admission path strictly beats drop-on-crash.
    // ------------------------------------------------------------------
    let churn_tc = TraceCfg::new(requests, 432_000, 45)
        .with_skew(1.0)
        .with_slo([0.5, 0.3, 0.2])
        .with_burst(32, 16)
        .with_churn(0.10);
    let churn_trace = serve::synth_trace(&churn_tc, ws.len());
    let churn_events = serve::synth_fleet_events(&churn_tc, &churn_trace, overload_fleet.len());
    assert!(
        !churn_events.is_empty(),
        "10% churn over {} arrivals must inject fleet events",
        churn_trace.len()
    );
    let interactive_offered = churn_trace
        .iter()
        .filter(|r| serve::class_index(r.priority()) == 0)
        .count();
    assert!(interactive_offered > 0, "churn trace needs interactive load");
    let churn_cells: [(&str, bool, bool); 3] = [
        ("no-churn", false, true),
        ("churn+readmit", true, true),
        ("churn+drop", true, false),
    ];
    let mut churn_rows: Vec<Json> = Vec::new();
    let mut churn_int: BTreeMap<&'static str, u64> = BTreeMap::new();
    println!(
        "churn matrix (m7:2,m4:2, 10% churn, {} fleet event(s), {} interactive offered):",
        churn_events.len(),
        interactive_offered
    );
    for (label, churned, readmit) in churn_cells {
        let cell_cfg = ServeCfg {
            fleet: overload_fleet.clone(),
            scheduler: SchedulerKind::SloAware,
            batcher: BatcherCfg {
                max_batch: 16,
                max_wait_cycles: 432_000,
                max_queue: 8,
                admission: AdmissionKind::ClassAware,
                preempt: true,
            },
            steal: true,
            readmit,
            ..ServeCfg::default()
        };
        let events: &[serve::FleetEvent] = if churned { &churn_events } else { &[] };
        let rep = serve::run_trace_full(&ws, &churn_trace, events, &cell_cfg)?;
        assert_eq!(
            rep.completed as u64 + rep.rejected_queue + rep.rejected_sram + rep.lost,
            churn_trace.len() as u64,
            "churn cell `{label}` must conserve requests"
        );
        if churned {
            assert!(
                rep.crashes > 0,
                "churn cell `{label}` saw no crashes — scenario is toothless"
            );
        }
        println!(
            "  {:>14}  completed {:>3}  interactive misses {:>3}  readmitted {:>3}  lost {:>3}  crashes {:>2}  migrations {:>3}",
            label,
            rep.completed,
            rep.class_misses(0),
            rep.readmissions(),
            rep.lost,
            rep.crashes,
            rep.migrations
        );
        churn_int.insert(label, rep.class_misses(0));
        let mut row = BTreeMap::new();
        row.insert("cell".into(), Json::Str(label.into()));
        row.insert("readmit".into(), Json::Num(if readmit { 1.0 } else { 0.0 }));
        row.insert("completed".into(), Json::Num(rep.completed as f64));
        row.insert(
            "interactive_misses".into(),
            Json::Num(rep.class_misses(0) as f64),
        );
        row.insert(
            "interactive_miss_rate".into(),
            Json::Num(rep.class_misses(0) as f64 / interactive_offered as f64),
        );
        row.insert("readmissions".into(), Json::Num(rep.readmissions() as f64));
        row.insert("lost_requests".into(), Json::Num(rep.lost as f64));
        row.insert("crashes".into(), Json::Num(rep.crashes as f64));
        row.insert("migrations".into(), Json::Num(rep.migrations as f64));
        row.insert("total_misses".into(), Json::Num(rep.total_misses() as f64));
        row.insert("throughput_rps".into(), Json::Num(rep.throughput_rps));
        churn_rows.push(Json::Obj(row));
    }
    println!();

    // ------------------------------------------------------------------
    // Event-loop replay speed: the event-heap core vs the `legacy_loop`
    // scan core on an m7:8,m4:8 fleet. The legacy cell runs per-image
    // inference, so it replays a shorter prefix of the same arrival
    // process (quarter length, floor 64, cap 2000); both rates are
    // per-request per second of host wall time, so they normalize.
    // ------------------------------------------------------------------
    let speed_fleet: Vec<DeviceCfg> = (0..16)
        .map(|i| {
            if i < 8 {
                DeviceCfg::stm32f746()
            } else {
                DeviceCfg::stm32f446()
            }
        })
        .collect();
    let speed_cfg = ServeCfg {
        fleet: speed_fleet,
        scheduler: SchedulerKind::SloAware,
        ..ServeCfg::default()
    };
    let speed_tc = |n: usize| {
        TraceCfg::new(n, 216_000, 46)
            .with_skew(1.0)
            .with_slo([0.5, 0.3, 0.2])
    };
    let speed_trace = serve::synth_trace(&speed_tc(requests), ws.len());
    let fast_rep = serve::run_trace(&ws, &speed_trace, &speed_cfg)?;
    let legacy_n = (requests / 4).max(64).min(2000).min(requests);
    let legacy_trace = serve::synth_trace(&speed_tc(legacy_n), ws.len());
    let legacy_cfg = ServeCfg {
        legacy_loop: true,
        ..speed_cfg.clone()
    };
    let legacy_rep = serve::run_trace(&ws, &legacy_trace, &legacy_cfg)?;
    let event_loop_speedup = if legacy_rep.replay_requests_per_sec > 0.0 {
        fast_rep.replay_requests_per_sec / legacy_rep.replay_requests_per_sec
    } else {
        f64::INFINITY
    };
    println!(
        "event-loop replay (m7:8,m4:8): {:.0} req/s over {} requests vs legacy scan loop {:.0} req/s over {} ({event_loop_speedup:.1}x)\n",
        fast_rep.replay_requests_per_sec,
        requests,
        legacy_rep.replay_requests_per_sec,
        legacy_n
    );

    // Host-side simulation speed (wall clock), for the record.
    let t = Bench::new(0, 3).run("replay", || {
        serve::run_trace(&ws, &trace, &cfg).expect("replay")
    });
    println!("host replay wall time: {}", t.mean_human());

    let mut o = BTreeMap::new();
    o.insert("bench".into(), Json::Str("serve_throughput".into()));
    o.insert("requests".into(), Json::Num(requests as f64));
    o.insert("devices".into(), Json::Num(cfg.fleet.len() as f64));
    o.insert("completed".into(), Json::Num(report.completed as f64));
    o.insert("throughput_rps".into(), Json::Num(report.throughput_rps));
    o.insert("p50_ms".into(), Json::Num(report.latency.p50_ms));
    o.insert("p95_ms".into(), Json::Num(report.latency.p95_ms));
    o.insert("p99_ms".into(), Json::Num(report.latency.p99_ms));
    o.insert("cache_hit_rate".into(), Json::Num(report.cache.hit_rate()));
    o.insert(
        "engine_compiles".into(),
        Json::Num(report.engine_compiles as f64),
    );
    o.insert("batch_speedup".into(), Json::Num(batch_speedup));
    o.insert("sim_wall_ms".into(), Json::Num(t.mean_ns / 1e6));
    o.insert(
        "replay_requests_per_sec".into(),
        Json::Num(fast_rep.replay_requests_per_sec),
    );
    o.insert(
        "legacy_requests_per_sec".into(),
        Json::Num(legacy_rep.replay_requests_per_sec),
    );
    o.insert("event_loop_speedup".into(), Json::Num(event_loop_speedup));
    o.insert("rows".into(), Json::Arr(rows));
    o.insert("energy_rows".into(), Json::Arr(energy_rows));
    o.insert("overload".into(), Json::Arr(overload_rows));
    o.insert("churn".into(), Json::Arr(churn_rows));
    println!("{}", Json::Obj(o).to_string_compact());

    // Qualitative guards the trajectory must keep.
    assert!(report.completed > 0, "no requests served");
    assert_eq!(
        report.cache.compiles, 2,
        "exactly one compilation per served model"
    );
    assert!(
        report.cache.hit_rate() > 0.9,
        "sustained traffic must hit the artifact cache ({:.2})",
        report.cache.hit_rate()
    );
    assert!(
        report.latency.p50_ms <= report.latency.p95_ms
            && report.latency.p95_ms <= report.latency.p99_ms,
        "percentiles must be ordered"
    );
    // Batching always saves device work (same inference cycles, fewer
    // per-invocation overheads); makespan can tie under light load, so
    // the guard is on total busy cycles.
    let busy = |r: &serve::ServeReport| -> u64 { r.per_device.iter().map(|d| d.busy_cycles).sum() };
    assert!(
        busy(&report) <= busy(&solo),
        "batched fleet must not do more device work ({} vs {})",
        busy(&report),
        busy(&solo)
    );
    // Scheduler-refactor acceptance: on the heterogeneous fleet under
    // deadline pressure, SLO-aware placement strictly reduces deadline
    // misses vs round-robin.
    let rr = misses[&("m7:2,m4:2".to_string(), "round-robin")];
    let slo = misses[&("m7:2,m4:2".to_string(), "slo-aware")];
    assert!(
        rr > 0,
        "scenario must create deadline pressure under round-robin (rr misses {rr})"
    );
    assert!(
        slo < rr,
        "slo-aware must strictly reduce deadline misses ({slo} vs {rr})"
    );
    // Energy-aware placement acceptance: on the heterogeneous fleet it
    // must strictly cut total joules vs slo-aware — by routing the
    // deadline-free share of the trace onto the efficient M4s — without
    // increasing the interactive-class (shed-inclusive) miss count.
    let slo_j = joules[&("m7:2,m4:2".to_string(), "slo-aware")];
    let energy_j = joules[&("m7:2,m4:2".to_string(), "energy-aware")];
    assert!(
        energy_j < slo_j,
        "energy-aware must strictly reduce fleet joules ({energy_j} vs {slo_j})"
    );
    let slo_int = interactive[&("m7:2,m4:2".to_string(), "slo-aware")];
    let energy_int = interactive[&("m7:2,m4:2".to_string(), "energy-aware")];
    assert!(
        energy_int <= slo_int,
        "energy savings must not cost interactive SLOs ({energy_int} vs {slo_int})"
    );
    // Overload-resilience acceptance: under the burst trace, FIFO
    // shedding must actually lose interactive deadlines, and class-aware
    // admission + preemption (+ stealing) must strictly cut the
    // shed-inclusive interactive miss count.
    let fifo_int = interactive_misses["fifo"];
    let resilient_int = interactive_misses["class+preempt+steal"];
    assert!(
        fifo_int > 0,
        "burst scenario must cost FIFO interactive deadlines (got {fifo_int})"
    );
    assert!(
        resilient_int < fifo_int,
        "class admission + preemption must strictly cut interactive misses ({resilient_int} vs {fifo_int})"
    );
    // Fault-injection acceptance: (a) under 10% churn with class-aware
    // crash re-admission, the interactive miss *rate* degrades by at
    // most 10 percentage points over the no-churn baseline; (b) the
    // re-admission path strictly beats naive drop-on-crash.
    let base_rate = churn_int["no-churn"] as f64 / interactive_offered as f64;
    let readmit_rate = churn_int["churn+readmit"] as f64 / interactive_offered as f64;
    assert!(
        readmit_rate <= base_rate + 0.10 + 1e-12,
        "churn degraded interactive misses beyond the 10pp bound ({readmit_rate:.3} vs baseline {base_rate:.3})"
    );
    assert!(
        churn_int["churn+readmit"] < churn_int["churn+drop"],
        "crash re-admission must strictly beat drop-on-crash on interactive misses ({} vs {})",
        churn_int["churn+readmit"],
        churn_int["churn+drop"]
    );
    // Event-driven serve-core acceptance: the heap-driven replay must
    // sustain at least twice the legacy scan loop's request rate.
    assert!(
        event_loop_speedup >= 2.0,
        "event-loop replay must be >=2x the legacy scan loop ({:.0} vs {:.0} req/s)",
        fast_rep.replay_requests_per_sec,
        legacy_rep.replay_requests_per_sec
    );
    Ok(())
}
