//! Serving-throughput trajectory bench.
//!
//! Replays the canonical mixed-fleet scenario (vgg_tiny on RP-SLBC +
//! mobilenet_tiny on int8 TinyEngine, 320 requests, 4 × STM32F746) and
//! emits one JSON summary line — requests/s in virtual MCU time, p95
//! latency, cache hit rate, compile count — so future PRs can track the
//! serving trajectory alongside the fig5–fig8 benches. A second
//! no-batching replay quantifies the dynamic-batching win.
//!
//! Regenerate with `cargo bench --bench serve_throughput`.

use std::collections::BTreeMap;

use mcu_mixq::ops::Method;
use mcu_mixq::serve::{self, BatcherCfg, ServeCfg, TraceCfg, Workload};
use mcu_mixq::util::bench::Bench;
use mcu_mixq::util::json::Json;

fn workloads() -> Vec<Workload> {
    vec![
        Workload::synth("vgg_tiny", Method::RpSlbc, 4, 101).unwrap(),
        Workload::synth("mobilenet_tiny", Method::TinyEngine, 8, 102).unwrap(),
    ]
}

fn main() -> mcu_mixq::Result<()> {
    let requests = std::env::var("MCU_MIXQ_SERVE_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(320usize);
    let ws = workloads();
    // ~3 ms mean offered gap: enough pressure that batching matters, not
    // enough to saturate four devices.
    let trace = serve::synth_trace(&TraceCfg::new(requests, 648_000, 42), ws.len());
    let cfg = ServeCfg::default();

    println!(
        "serve_throughput — {} requests, {} devices, mixed fleet\n",
        requests, cfg.devices
    );
    let report = serve::run_trace(&ws, &trace, &cfg)?;
    println!("{}", report.render());

    // Same trace with batching disabled (batch = 1, no wait window).
    let solo_cfg = ServeCfg {
        batcher: BatcherCfg {
            max_batch: 1,
            max_wait_cycles: 1,
            max_queue: cfg.batcher.max_queue,
        },
        ..cfg.clone()
    };
    let solo = serve::run_trace(&ws, &trace, &solo_cfg)?;
    let batch_speedup = solo.makespan_cycles as f64 / report.makespan_cycles as f64;
    println!(
        "dynamic batching: makespan {} vs unbatched {} cycles ({batch_speedup:.3}x)\n",
        report.makespan_cycles, solo.makespan_cycles
    );

    // Host-side simulation speed (wall clock), for the record.
    let t = Bench::new(0, 3).run("replay", || {
        serve::run_trace(&ws, &trace, &cfg).expect("replay")
    });
    println!("host replay wall time: {}", t.mean_human());

    let mut o = BTreeMap::new();
    o.insert("bench".into(), Json::Str("serve_throughput".into()));
    o.insert("requests".into(), Json::Num(requests as f64));
    o.insert("devices".into(), Json::Num(cfg.devices as f64));
    o.insert("completed".into(), Json::Num(report.completed as f64));
    o.insert("throughput_rps".into(), Json::Num(report.throughput_rps));
    o.insert("p50_ms".into(), Json::Num(report.latency.p50_ms));
    o.insert("p95_ms".into(), Json::Num(report.latency.p95_ms));
    o.insert("p99_ms".into(), Json::Num(report.latency.p99_ms));
    o.insert("cache_hit_rate".into(), Json::Num(report.cache.hit_rate()));
    o.insert(
        "engine_compiles".into(),
        Json::Num(report.engine_compiles as f64),
    );
    o.insert("batch_speedup".into(), Json::Num(batch_speedup));
    o.insert("sim_wall_ms".into(), Json::Num(t.mean_ns / 1e6));
    println!("{}", Json::Obj(o).to_string_compact());

    // Qualitative guards the trajectory must keep.
    assert!(report.completed > 0, "no requests served");
    assert_eq!(
        report.cache.compiles, 2,
        "exactly one compilation per served model"
    );
    assert!(
        report.cache.hit_rate() > 0.9,
        "sustained traffic must hit the artifact cache ({:.2})",
        report.cache.hit_rate()
    );
    assert!(
        report.latency.p50_ms <= report.latency.p95_ms
            && report.latency.p95_ms <= report.latency.p99_ms,
        "percentiles must be ordered"
    );
    // Batching always saves device work (same inference cycles, fewer
    // per-invocation overheads); makespan can tie under light load, so
    // the guard is on total busy cycles.
    let busy = |r: &serve::ServeReport| -> u64 { r.per_device.iter().map(|d| d.busy_cycles).sum() };
    assert!(
        busy(&report) <= busy(&solo),
        "batched fleet must not do more device work ({} vs {})",
        busy(&report),
        busy(&solo)
    );
    Ok(())
}
