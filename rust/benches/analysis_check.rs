//! Static-analyzer throughput bench.
//!
//! `analysis::analyze` sits on the registry's compile path (one pass per
//! key) and will sit in the NAS search's inner loop as the legality
//! oracle, so it must stay orders of magnitude cheaper than the compile
//! it audits. This bench times the full pass over the zoo backbones at
//! every SLBC bitwidth and asserts (a) zero Error findings on clean
//! artifacts and (b) analysis cost well under compile cost.
//!
//! Regenerate with `cargo bench --bench analysis_check`.

use mcu_mixq::analysis;
use mcu_mixq::engine::CompiledModel;
use mcu_mixq::models;
use mcu_mixq::ops::Method;
use mcu_mixq::quant::BitConfig;
use mcu_mixq::target::Target;
use mcu_mixq::util::bench::{Bench, Table};
use mcu_mixq::util::prng::Rng;

fn main() {
    let bench = Bench::fast();
    let m7 = Target::lookup("stm32f746").unwrap();
    let mut table = Table::new(vec![
        "model", "method", "bits", "analyze ns", "compile ns", "ratio", "errors",
    ]);
    println!("analysis_check — static analyzer cost per compiled artifact\n");

    for model in [models::vgg_tiny(10, 16), models::mobilenet_tiny(2, 16)] {
        let mut rng = Rng::new(1000);
        let params: Vec<f32> =
            (0..model.param_count).map(|_| rng.normal() * 0.1).collect();
        for method in [Method::Slbc, Method::RpSlbc] {
            for bits in [2u8, 4, 8] {
                let cfg = BitConfig::uniform(model.layers.len(), bits);
                let compile_t = bench.run("compile", || {
                    CompiledModel::compile_for(&model, &params, &cfg, method, m7).unwrap()
                });
                let cm =
                    CompiledModel::compile_for(&model, &params, &cfg, method, m7).unwrap();
                let analyze_t = bench.run("analyze", || analysis::analyze(&cm));
                let rep = analysis::analyze(&cm);
                assert_eq!(
                    rep.errors(),
                    0,
                    "{}/{}/w{bits}: {:?}",
                    model.name,
                    method.name(),
                    rep.error_rules()
                );
                table.row(vec![
                    model.name.clone(),
                    method.name().to_string(),
                    bits.to_string(),
                    format!("{:.0}", analyze_t.mean_ns),
                    format!("{:.0}", compile_t.mean_ns),
                    format!("{:.2}x", analyze_t.mean_ns / compile_t.mean_ns.max(1.0)),
                    rep.errors().to_string(),
                ]);
            }
        }
    }
    table.print();
    println!("\nall artifacts statically clean; analyzer stays off the hot path");
}
