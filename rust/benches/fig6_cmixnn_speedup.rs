//! Fig. 6 — SLBC vs CMix-NN equivalent-operations ratio over the
//! (weight-bits, activation-bits) grid.
//!
//! Protocol (paper §V.B): compare *theoretical throughput* — the
//! equivalent number of useful operations one SIMD instruction slot
//! performs, packing/segmentation overheads included. The paper reports
//! up to ≈1.5× over CMix-NN on most quantization combinations.
//!
//! Two views are printed: the 32-bit-SIMD-register view (the paper's
//! hardware assumption — strategy-vs-strategy) and the fully adaptive
//! view (lane + carrier adaptation of §IV.C, which additionally exploits
//! the M7's long-multiply datapath).
//!
//! Regenerate with `cargo bench --bench fig6_cmixnn_speedup`.

use mcu_mixq::mcu::{Counter, CycleModel};
use mcu_mixq::models::vgg_tiny;
use mcu_mixq::ops::Method;
use mcu_mixq::simd::adaptive::{
    cmixnn_equivalent_ops, slbc_equivalent_ops, slbc_equivalent_ops_simd32,
};
use mcu_mixq::util::bench::Table;
use mcu_mixq::util::prng::Rng;

fn grid(title: &str, f: impl Fn(u32, u32) -> f64) {
    println!("{title}");
    let mut t = Table::new(
        std::iter::once("w\\a".to_string())
            .chain([2u32, 4, 8].iter().map(|a| format!("{a}b")))
            .collect::<Vec<_>>(),
    );
    for &w in &[2u32, 4, 8] {
        let mut row = vec![format!("{w}b")];
        for &a in &[2u32, 4, 8] {
            row.push(format!("{:.2}x", f(w, a)));
        }
        t.row(row);
    }
    t.print();
    println!();
}

/// Measured cross-check: cycle ratio of the two kernels on a real layer.
/// The layer geometry is built once and reused across the whole grid
/// (artifact reuse per the ROADMAP bench item); operands are shared by
/// both methods within a cell — both kernels are bit-exact, so only the
/// charged instruction mix differs.
fn measured_ratio(l: &mcu_mixq::models::LayerSpec, w: u8, a: u8) -> f64 {
    let cm = CycleModel::cortex_m7();
    let mut rng = Rng::new(7 + w as u64 * 8 + a as u64);
    let x: Vec<u32> = (0..l.in_elems()).map(|_| rng.below(1 << a) as u32).collect();
    let lim = (1i64 << (w - 1)) - 1;
    let wt: Vec<i32> = (0..l.w_size)
        .map(|_| (rng.below(2 * lim as u64 + 1) as i64 - lim) as i32)
        .collect();
    let mut c1 = Counter::new();
    Method::CmixNn.run_layer(&x, &wt, l, w, a, &mut c1);
    let mut c2 = Counter::new();
    Method::Slbc.run_layer(&x, &wt, l, w, a, &mut c2);
    c1.cycles(&cm) as f64 / c2.cycles(&cm) as f64
}

fn main() {
    println!("Fig. 6 — SLBC speedup over CMix-NN (equivalent ops per SIMD slot)\n");

    grid("ratio, 32-bit SIMD registers (paper's comparison):", |w, a| {
        slbc_equivalent_ops_simd32(w, a, 3) / cmixnn_equivalent_ops(w, a)
    });
    grid("ratio, fully adaptive packing (§IV.C, incl. long-multiply):", |w, a| {
        slbc_equivalent_ops(w, a, 3) / cmixnn_equivalent_ops(w, a)
    });
    let mut conv3 = vgg_tiny(10, 16).layers[2].clone();
    conv3.macs = conv3.compute_macs();
    grid("measured cycle ratio on VGG-Tiny conv3 (end-to-end kernels):", |w, a| {
        measured_ratio(&conv3, w as u8, a as u8)
    });

    // Qualitative guards of the figure.
    //
    // 32-bit view: in-lane packing wins where sub-byte fields are dense
    // (2-bit rows/cols); at (4,4)+ a 32-bit lane holds too few fields and
    // CMix-NN's SMLAD catches up — which is exactly why §IV.C adapts the
    // carrier instead of fixing it.
    let r22 = slbc_equivalent_ops_simd32(2, 2, 3) / cmixnn_equivalent_ops(2, 2);
    assert!(r22 > 1.0, "32-bit SLBC must win at (2,2): ratio {r22:.2}");
    let r88 = slbc_equivalent_ops_simd32(8, 8, 3) / cmixnn_equivalent_ops(8, 8);
    assert!(r22 > r88, "advantage must concentrate at low bitwidths");
    // Adaptive view (what MCU-MixQ actually deploys): never lose.
    for &w in &[2u32, 4, 8] {
        for &a in &[2u32, 4, 8] {
            let r = slbc_equivalent_ops(w, a, 3) / cmixnn_equivalent_ops(w, a);
            assert!(
                r >= 1.0,
                "adaptive SLBC must not lose to CMix-NN at ({w},{a}): ratio {r:.2}"
            );
        }
    }
    println!("(paper: up to ~1.5x in most combinations; advantage grows at low bits)");
}
