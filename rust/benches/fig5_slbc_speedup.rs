//! Fig. 5 — SLBC speedup over naive and plain-SIMD convolution vs
//! bitwidth.
//!
//! Protocol (paper §V.B): single convolution layers executed at every
//! bitwidth 2–8; naive and plain-SIMD convolution have no sub-byte
//! support, so their latency is constant below 8 bits, while SLBC's cost
//! shrinks with the packing density. The paper reports average speedups
//! of ≈4× over naive and ≈2× over plain SIMD.
//!
//! Regenerate with `cargo bench --bench fig5_slbc_speedup`.

use mcu_mixq::mcu::{Counter, CycleModel};
use mcu_mixq::models::{vgg_tiny, LayerSpec};
use mcu_mixq::ops::Method;
use mcu_mixq::util::bench::Table;
use mcu_mixq::util::prng::Rng;

fn bench_layer() -> LayerSpec {
    // VGG-Tiny conv3 geometry (8×8×16 → 8×8×32, 3×3) — a mid-network
    // conv representative of where MCUs spend their cycles.
    let mut l = vgg_tiny(10, 16).layers[2].clone();
    l.macs = l.compute_macs();
    l
}

/// One operand set per bitwidth, reused by every method (the artifact-
/// reuse discipline of the ROADMAP bench item: all kernels are bit-exact,
/// so sharing inputs changes nothing but removes per-trial regeneration;
/// cycle charges are geometry-determined either way).
fn operands(l: &LayerSpec, bits: u8, seed: u64) -> (Vec<u32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let x: Vec<u32> = (0..l.in_elems()).map(|_| rng.below(1 << bits) as u32).collect();
    let lim = (1i64 << (bits - 1)) - 1;
    let w: Vec<i32> = (0..l.w_size)
        .map(|_| (rng.below(2 * lim as u64 + 1) as i64 - lim) as i32)
        .collect();
    (x, w)
}

fn run(method: Method, l: &LayerSpec, io: &(Vec<u32>, Vec<i32>), bits: u8, cm: &CycleModel) -> u64 {
    let mut ctr = Counter::new();
    method.run_layer(&io.0, &io.1, l, bits, bits, &mut ctr);
    ctr.cycles(cm)
}

fn main() {
    let cm = CycleModel::cortex_m7();
    let l = bench_layer();
    println!(
        "Fig. 5 — SLBC speedup over naive / plain-SIMD convolution\n\
         layer: {} ({}×{}×{} -> {}, k={}, {} MACs)\n",
        l.name, l.in_h, l.in_w, l.cin, l.cout, l.k, l.macs
    );

    let mut t = Table::new(vec![
        "bits", "naive cyc", "simd cyc", "slbc cyc", "vs naive", "vs simd",
    ]);
    let mut sp_naive = Vec::new();
    let mut sp_simd = Vec::new();
    for bits in 2..=8u8 {
        let io = operands(&l, bits, 10 + bits as u64);
        let c_naive = run(Method::Naive, &l, &io, bits, &cm);
        let c_simd = run(Method::Simd, &l, &io, bits, &cm);
        let c_slbc = run(Method::Slbc, &l, &io, bits, &cm);
        let rn = c_naive as f64 / c_slbc as f64;
        let rs = c_simd as f64 / c_slbc as f64;
        sp_naive.push(rn);
        sp_simd.push(rs);
        t.row(vec![
            format!("{bits}"),
            format!("{c_naive}"),
            format!("{c_simd}"),
            format!("{c_slbc}"),
            format!("{rn:.2}x"),
            format!("{rs:.2}x"),
        ]);
    }
    t.print();

    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "\naverage speedup: {:.2}x over naive (paper: ~4x), {:.2}x over plain SIMD (paper: ~2x)",
        avg(&sp_naive),
        avg(&sp_simd)
    );
    // Sanity guards: the figure's qualitative claims.
    assert!(avg(&sp_naive) > avg(&sp_simd), "naive must be the slower baseline");
    assert!(sp_naive[0] > sp_naive[6], "speedup must grow as bits shrink");
}
