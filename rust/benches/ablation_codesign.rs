//! Ablations of MCU-MixQ's design choices (DESIGN.md §8):
//!
//! 1. **Adaptive lane/carrier selection (§IV.C)** — cost/MAC of the
//!    adaptive plan vs each fixed lane configuration across bitwidths.
//! 2. **Field-stride widening** — minimal-field packing vs the chosen
//!    wider stride (guard bits buy in-register accumulation).
//! 3. **Lifetime SRAM planner** — peak arena vs all-buffers-live across
//!    backbones and bitwidths.
//! 4. **Packing-reuse sensitivity** — how the amortization constant
//!    shifts the SLBC cost model.
//!
//! Regenerate with `cargo bench --bench ablation_codesign`.

use mcu_mixq::engine::{plan_memory, Graph, PlanStrategy};
use mcu_mixq::models::{mobilenet_tiny, vgg_tiny};
use mcu_mixq::quant::BitConfig;
use mcu_mixq::simd::adaptive::{best_plan, best_plan_with};
use mcu_mixq::simd::packing::LaneCfg;
use mcu_mixq::simd::poly::field_width;
use mcu_mixq::util::bench::Table;

fn main() {
    // ---- 1. adaptive vs fixed lane configurations ----------------------
    println!("Ablation 1 — adaptive lane/carrier selection (cost per MAC, k=3):\n");
    let mut t = Table::new(vec!["bits (w=a)", "4x8b", "2x16b", "1x32b", "64b", "adaptive"]);
    for bits in 2..=8u32 {
        let mut row = vec![format!("{bits}")];
        for &cfg in LaneCfg::all() {
            let c = best_plan_with(&[cfg], bits, bits, 3)
                .map(|p| format!("{:.3}", p.cost_per_mac))
                .unwrap_or_else(|| "—".into());
            row.push(c);
        }
        let a = best_plan(bits, bits, 3).unwrap();
        row.push(format!("{:.3}", a.cost_per_mac));
        t.row(row);
    }
    t.print();
    for bits in 2..=8u32 {
        let a = best_plan(bits, bits, 3).unwrap().cost_per_mac;
        for &cfg in LaneCfg::all() {
            if let Some(p) = best_plan_with(&[cfg], bits, bits, 3) {
                assert!(a <= p.cost_per_mac + 1e-9, "adaptive must dominate at {bits}b");
            }
        }
    }
    println!("(adaptive = min over configurations, per §IV.C)\n");

    // ---- 2. field-stride widening ---------------------------------------
    println!("Ablation 2 — field stride: minimal vs chosen (guard bits buy accumulation):\n");
    let mut t = Table::new(vec!["bits", "min field", "chosen", "accum depth", "cost/MAC gain"]);
    for bits in 2..=6u32 {
        let minf = field_width(bits, bits, 3);
        let plan = best_plan(bits, bits, 3).unwrap();
        let min_plan = LaneCfg::all()
            .iter()
            .filter_map(|&c| best_plan_with(&[c], bits, bits, 3))
            .filter(|p| p.field == field_width(bits, bits, 3))
            .map(|p| p.cost_per_mac)
            .fold(f64::INFINITY, f64::min);
        let gain = if min_plan.is_finite() {
            format!("{:.2}x", min_plan / plan.cost_per_mac)
        } else {
            "n/a".into()
        };
        t.row(vec![
            format!("{bits}"),
            format!("{minf}"),
            format!("{}", plan.field),
            format!("{}", plan.accum_depth),
            gain,
        ]);
    }
    t.print();
    println!();

    // ---- 3. memory planner ----------------------------------------------
    println!("Ablation 3 — lifetime SRAM planner vs all-buffers-live:\n");
    let mut t = Table::new(vec!["backbone", "bits", "all-live KB", "planned KB", "saving"]);
    for model in [vgg_tiny(10, 16), mobilenet_tiny(2, 16)] {
        for bits in [2u8, 4, 8] {
            let g = Graph::build(&model, &BitConfig::uniform(model.num_layers(), bits));
            let all = plan_memory(&g, PlanStrategy::AllLive).peak_bytes;
            let plan = plan_memory(&g, PlanStrategy::Lifetime).peak_bytes;
            t.row(vec![
                model.name.clone(),
                format!("{bits}"),
                format!("{:.2}", all as f64 / 1024.0),
                format!("{:.2}", plan as f64 / 1024.0),
                format!("{:.2}x", all as f64 / plan as f64),
            ]);
            assert!(plan < all);
        }
    }
    t.print();
    println!("(the Table I peak-memory mechanism: TinyEngine/MCU-MixQ plan, libraries don't)\n");

    // ---- 4. packing-reuse sensitivity ------------------------------------
    println!("Ablation 4 — packing amortization (output-channel reuse of packed rows):");
    println!(
        "  PACK_REUSE = {} (see simd::adaptive); with reuse r the packing term\n\
         \x20 scales as pack_ops/r — at r=1 packing would dominate sub-byte gains,\n\
         \x20 at r≥4 (any real conv: 16–64 output channels) it is noise.",
        mcu_mixq::simd::adaptive::PACK_REUSE
    );
    for bits in [2u32, 4, 8] {
        let p = best_plan(bits, bits, 3).unwrap();
        let pack = p.conv.pack_ops_per_instr() as f64;
        let macs = p.macs_per_instr as f64;
        println!(
            "  {bits}b: pack {pack:.0} ops / {macs:.0} MACs per multiply -> r=1: +{:.2}, r=4: +{:.2}, r=16: +{:.2} cost/MAC",
            pack / macs,
            pack / 4.0 / macs,
            pack / 16.0 / macs
        );
    }
}
