//! Fig. 8 — searched mixed-precision configurations vs baselines.
//!
//! **Part A (always runs, no artifacts):** the native Pareto search
//! (`nas::search::native_search`) on both registry targets, compared
//! against the uniform 8-bit baseline on predicted cycles, model bytes
//! (flash) and the SQNR accuracy proxy. Asserts the paper's headline
//! shape: the best-cycles Pareto point strictly beats uniform int8 on
//! cycles at equal-or-smaller flash, and every front point passes the
//! static analyzer with zero Errors.
//!
//! **Part B (needs `artifacts/`):** the original EdMIPS-MAC-proxy vs
//! SIMD-aware (Eq. 12) supernet comparison with QAT accuracy (paper
//! §V.C: SIMD-aware reaches lower average bitwidths at +2.3% Top-1).
//! Skipped with a note when the PJRT artifacts are absent.
//!
//! Step counts can be overridden with `MCU_MIXQ_SEARCH_STEPS` /
//! `MCU_MIXQ_QAT_STEPS` (part B) and `MCU_MIXQ_NAS_GENS` (part A).
//!
//! Regenerate with `cargo bench --bench fig8_nas_configs`.

use std::collections::BTreeMap;

use mcu_mixq::analysis;
use mcu_mixq::coordinator::qat::QatCfg;
use mcu_mixq::coordinator::{QatRunner, SearchCfg, SupernetSearch};
use mcu_mixq::engine::CompiledModel;
use mcu_mixq::models::vgg_tiny;
use mcu_mixq::nas::search::{native_search, NativeSearchCfg, SearchOutcome};
use mcu_mixq::nas::CostProxy;
use mcu_mixq::ops::Method;
use mcu_mixq::perf::PerfModel;
use mcu_mixq::quant::BitConfig;
use mcu_mixq::runtime::{ArtifactStore, Runtime};
use mcu_mixq::target::Target;
use mcu_mixq::util::bench::Table;
use mcu_mixq::util::json::Json;
use mcu_mixq::util::prng::Rng;

fn env_steps(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// One JSON row of the searched-vs-uniform comparison.
fn row(target: &str, config: &str, cycles: u64, model_bytes: usize, acc_db: f64, avg_w: f64, avg_a: f64) -> Json {
    let mut r = BTreeMap::new();
    r.insert("target".into(), Json::Str(target.into()));
    r.insert("config".into(), Json::Str(config.into()));
    r.insert("cycles".into(), Json::Num(cycles as f64));
    r.insert("model_bytes".into(), Json::Num(model_bytes as f64));
    r.insert("accuracy_proxy".into(), Json::Num(acc_db));
    r.insert("avg_wbits".into(), Json::Num(avg_w));
    r.insert("avg_abits".into(), Json::Num(avg_a));
    Json::Obj(r)
}

/// Part A: native Pareto search vs uniform int8, no artifacts needed.
fn native_part(rows: &mut Vec<Json>) -> mcu_mixq::Result<Vec<SearchOutcome>> {
    let model = vgg_tiny(10, 16);
    let mut rng = Rng::new(1000);
    let params: Vec<f32> = (0..model.param_count).map(|_| rng.normal() * 0.1).collect();

    let mut cfg = NativeSearchCfg::smoke(7);
    cfg.generations = env_steps("MCU_MIXQ_NAS_GENS", cfg.generations);

    println!(
        "Part A — native Pareto search on {} via {} (seed {}, {} generation(s)):\n",
        model.name,
        cfg.method.name(),
        cfg.seed,
        cfg.generations
    );

    let mut outcomes = Vec::new();
    for name in ["stm32f746", "stm32f446"] {
        let target = Target::resolve(name)?;
        let out = native_search(&model, &params, target, &cfg)?;
        let best = out.best_cycles().clone();
        let u8b = &out.uniform8;

        let mut t = Table::new(vec![
            "config", "cycles", "model KB", "SQNR dB", "avg w", "avg a",
        ]);
        t.row(vec![
            "searched (best cycles)".into(),
            format!("{}", best.obj.cycles),
            format!("{:.1}", best.obj.flash_total_bytes as f64 / 1024.0),
            format!("{:.1}", best.obj.accuracy_proxy_db),
            format!("{:.2}", best.cfg.avg_wbits()),
            format!("{:.2}", best.cfg.avg_abits()),
        ]);
        let n = model.num_layers();
        let ucfg = BitConfig::uniform(n, 8);
        t.row(vec![
            "uniform int8".into(),
            format!("{}", u8b.cycles),
            format!("{:.1}", u8b.flash_total_bytes as f64 / 1024.0),
            format!("{:.1}", u8b.accuracy_proxy_db),
            "8.00".into(),
            "8.00".into(),
        ]);
        println!("{name} ({} Pareto point(s), {} scored / {} pruned):", out.front.len(), out.evaluated, out.pruned);
        t.print();
        println!(
            "  speedup {:.2}x at {:.2}x flash\n",
            u8b.cycles as f64 / best.obj.cycles as f64,
            best.obj.flash_total_bytes as f64 / u8b.flash_total_bytes as f64
        );

        rows.push(row(
            name,
            "searched",
            best.obj.cycles,
            best.obj.flash_total_bytes,
            best.obj.accuracy_proxy_db,
            best.cfg.avg_wbits(),
            best.cfg.avg_abits(),
        ));
        rows.push(row(
            name,
            "uniform8",
            u8b.cycles,
            u8b.flash_total_bytes,
            u8b.accuracy_proxy_db,
            ucfg.avg_wbits(),
            ucfg.avg_abits(),
        ));

        // Acceptance guards: searched strictly beats uniform int8 on
        // cycles at equal-or-smaller flash...
        assert!(
            best.obj.cycles < u8b.cycles,
            "{name}: best-cycles point ({}) must beat uniform int8 ({})",
            best.obj.cycles,
            u8b.cycles
        );
        assert!(
            best.obj.flash_total_bytes <= u8b.flash_total_bytes,
            "{name}: searched flash must not exceed uniform int8"
        );
        // ...and every front point re-proves analyzer-clean.
        for p in &out.front {
            let cm = CompiledModel::compile_unbounded_for(&model, &params, &p.cfg, cfg.method, target);
            let report = analysis::analyze(&cm);
            assert_eq!(
                report.errors(),
                0,
                "{name}: front point w={:?} a={:?} has analyzer Errors: {:?}",
                p.cfg.wbits,
                p.cfg.abits,
                report.error_rules()
            );
        }
        outcomes.push(out);
    }
    Ok(outcomes)
}

/// Part B: the PJRT supernet comparison (needs `artifacts/`).
fn supernet_part() -> mcu_mixq::Result<()> {
    let store = match ArtifactStore::open("artifacts") {
        Ok(s) => s,
        Err(_) => {
            println!("Part B — skipped: no artifacts/ (run tools/export_artifacts.py to enable the PJRT supernet comparison)");
            return Ok(());
        }
    };
    let rt = Runtime::cpu()?;
    let arts = store.backbone("vgg_tiny")?;

    let mut scfg = SearchCfg::default();
    scfg.steps = env_steps("MCU_MIXQ_SEARCH_STEPS", 150);
    let mut qcfg = QatCfg::default();
    qcfg.steps = env_steps("MCU_MIXQ_QAT_STEPS", 250);

    println!(
        "Part B — EdMIPS vs SIMD-aware supernet search on {} ({} search / {} QAT steps)\n",
        arts.model.name, scfg.steps, qcfg.steps
    );

    let pm = PerfModel::cortex_m7();
    let runner = QatRunner::new(&rt, &arts, qcfg.seed)?;
    let mut results = Vec::new();
    for proxy in [CostProxy::EdMipsMacs, CostProxy::SimdAware(pm, Method::RpSlbc)] {
        let search = SupernetSearch::new(&rt, &arts, proxy, scfg.seed)?;
        let out = search.run(&scfg)?;
        let qat = runner.run(&out.params, &out.config, &qcfg)?;
        println!(
            "{}: searched w={:?} a={:?}",
            proxy.name(),
            out.config.wbits,
            out.config.abits
        );
        results.push((proxy.name(), out, qat));
    }

    println!();
    let mut t = Table::new(vec![
        "explorer", "avg wbits", "avg abits", "predicted SLBC cost", "QAT accuracy",
    ]);
    let mut rows = Vec::new();
    for (name, out, qat) in &results {
        let cost = pm.model_complexity(&arts.model, Method::RpSlbc, &out.config);
        t.row(vec![
            name.to_string(),
            format!("{:.2}", out.config.avg_wbits()),
            format!("{:.2}", out.config.avg_abits()),
            format!("{cost:.3e}"),
            format!("{:.1}%", qat.eval_acc * 100.0),
        ]);
        rows.push((out.config.clone(), qat.eval_acc, cost));
    }
    t.print();

    let (edmips, simd) = (&rows[0], &rows[1]);
    println!(
        "\nSIMD-aware vs EdMIPS: Δacc {:+.1}pp, predicted-latency ratio {:.2}x",
        (simd.1 - edmips.1) * 100.0,
        edmips.2 / simd.2
    );
    println!("(paper: lower average bitwidths at equal-or-better accuracy, +2.3% Top-1)");
    Ok(())
}

fn main() -> mcu_mixq::Result<()> {
    let mut rows: Vec<Json> = Vec::new();
    let outcomes = native_part(&mut rows)?;
    supernet_part()?;

    let mut o = BTreeMap::new();
    o.insert("bench".into(), Json::Str("fig8_nas_configs".into()));
    o.insert("rows".into(), Json::Arr(rows));
    o.insert(
        "front_sizes".into(),
        Json::Arr(
            outcomes
                .iter()
                .map(|s| Json::Num(s.front.len() as f64))
                .collect(),
        ),
    );
    println!("{}", Json::Obj(o).to_string_compact());
    Ok(())
}
