//! Fig. 8 — quantization configurations searched by the EdMIPS MAC proxy
//! vs the SIMD-aware (Eq. 12) explorer, plus their QAT accuracy.
//!
//! Protocol (paper §V.C): run the differentiable search twice on the same
//! backbone/supernet, changing only the complexity signal; QAT both
//! selected configs and compare per-layer bitwidths, average bitwidth,
//! predicted SLBC latency and final accuracy. The paper reports the
//! SIMD-aware explorer reaching lower average bitwidths at +2.3% accuracy.
//!
//! Needs `artifacts/` (PJRT programs). Step counts can be overridden with
//! `MCU_MIXQ_SEARCH_STEPS` / `MCU_MIXQ_QAT_STEPS`.
//!
//! Regenerate with `cargo bench --bench fig8_nas_configs`.

use mcu_mixq::coordinator::qat::QatCfg;
use mcu_mixq::coordinator::{QatRunner, SearchCfg, SupernetSearch};
use mcu_mixq::nas::CostProxy;
use mcu_mixq::ops::Method;
use mcu_mixq::perf::PerfModel;
use mcu_mixq::runtime::{ArtifactStore, Runtime};
use mcu_mixq::util::bench::Table;

fn env_steps(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn main() -> mcu_mixq::Result<()> {
    let store = ArtifactStore::open("artifacts")?;
    let rt = Runtime::cpu()?;
    let arts = store.backbone("vgg_tiny")?;

    let mut scfg = SearchCfg::default();
    scfg.steps = env_steps("MCU_MIXQ_SEARCH_STEPS", 150);
    let mut qcfg = QatCfg::default();
    qcfg.steps = env_steps("MCU_MIXQ_QAT_STEPS", 250);

    println!(
        "Fig. 8 — EdMIPS vs SIMD-aware quantization search on {} ({} search / {} QAT steps)\n",
        arts.model.name, scfg.steps, qcfg.steps
    );

    let pm = PerfModel::cortex_m7();
    let runner = QatRunner::new(&rt, &arts, qcfg.seed)?;
    let mut results = Vec::new();
    for proxy in [CostProxy::EdMipsMacs, CostProxy::SimdAware(pm, Method::RpSlbc)] {
        let search = SupernetSearch::new(&rt, &arts, proxy, scfg.seed)?;
        let out = search.run(&scfg)?;
        let qat = runner.run(&out.params, &out.config, &qcfg)?;
        println!(
            "{}: searched w={:?} a={:?}",
            proxy.name(),
            out.config.wbits,
            out.config.abits
        );
        results.push((proxy.name(), out, qat));
    }

    println!();
    let mut t = Table::new(vec![
        "explorer", "avg wbits", "avg abits", "predicted SLBC cost", "QAT accuracy",
    ]);
    let mut rows = Vec::new();
    for (name, out, qat) in &results {
        let cost = pm.model_complexity(&arts.model, Method::RpSlbc, &out.config);
        t.row(vec![
            name.to_string(),
            format!("{:.2}", out.config.avg_wbits()),
            format!("{:.2}", out.config.avg_abits()),
            format!("{cost:.3e}"),
            format!("{:.1}%", qat.eval_acc * 100.0),
        ]);
        rows.push((out.config.clone(), qat.eval_acc, cost));
    }
    t.print();

    let (edmips, simd) = (&rows[0], &rows[1]);
    println!(
        "\nSIMD-aware vs EdMIPS: Δacc {:+.1}pp, predicted-latency ratio {:.2}x",
        (simd.1 - edmips.1) * 100.0,
        edmips.2 / simd.2
    );
    println!("(paper: lower average bitwidths at equal-or-better accuracy, +2.3% Top-1)");
    Ok(())
}
