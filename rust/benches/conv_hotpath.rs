//! Conv hot-path throughput bench (per-PR trend line).
//!
//! Measures the rolling-row SLBC pipeline (pre-packed kernels + reusable
//! scratch — the steady state of a serve request) against the pre-PR
//! operator retained in `ops::slbc::legacy`, reporting host ns/layer and
//! modeled cycles per method and bitwidth, plus one JSON summary line.
//!
//! Acceptance guard: ≥ 2× mean host-side throughput on stride-1 k=3 conv
//! layers. Smoke mode (`MCU_MIXQ_SMOKE=1`) keeps the trend line cheap and
//! swaps the guard for the deterministic modeled-cycle invariant —
//! single-repeat wall-clock means on tiny layers are too noisy to gate on.
//!
//! Regenerate with `cargo bench --bench conv_hotpath`.

use mcu_mixq::perf::conv_hotpath::{run, ConvBenchCfg};

fn main() {
    let smoke = std::env::var("MCU_MIXQ_SMOKE").map(|v| v == "1").unwrap_or(false);
    let mut cfg = if smoke {
        ConvBenchCfg::smoke()
    } else {
        ConvBenchCfg::default()
    };
    if let Ok(r) = std::env::var("MCU_MIXQ_BENCH_REPEATS") {
        if let Ok(n) = r.parse() {
            cfg.repeats = n;
        }
    }

    println!("conv_hotpath — rolling-row SLBC pipeline vs pre-PR operator\n");
    let rep = run(&cfg);
    print!("{}", rep.render());
    let sp = rep.mean_speedup_conv3x3();
    println!(
        "\nmean host speedup on stride-1 k=3 convs: {sp:.2}x  (modeled cycle ratio {:.3}x)",
        rep.mean_cycle_ratio()
    );
    println!("{}", rep.to_json().to_string_compact());

    // The acceptance guard of the rolling-row refactor: deterministic
    // cycle invariant always, the >= 2x wall-clock bar in full mode only.
    rep.check_cycle_invariant().expect("cycle invariant");
    if !smoke {
        rep.check_speedup(2.0).expect("speedup acceptance");
    }
}
