//! Table I — end-to-end comparison with previous frameworks.
//!
//! Protocol (paper §V.A): for each backbone (VGG-Tiny × synth-CIFAR,
//! MobileNet-Tiny × synth-VWW) run the full MCU-MixQ pipeline (search →
//! QAT → deploy) and deploy the same trained model through CMix-NN,
//! WPC&DDD and TinyEngine; report peak memory, flash, clocks, latency
//! @216 MHz and accuracy. The paper's headline: 2.1× over CMix-NN, 1.4×
//! over TinyEngine(MCUNet) at the same resource/accuracy constraints.
//!
//! Artifact reuse: each method's row is produced from **one**
//! `CompiledModel` (compile → run on the artifact, `deploy_all_methods`),
//! so no per-trial recompilation happens anywhere in this protocol; the
//! pre-packed kernel registers of the SLBC rows ride along in the
//! artifact's `KernelCache`.
//!
//! Needs `artifacts/`. Step counts can be overridden with
//! `MCU_MIXQ_SEARCH_STEPS` / `MCU_MIXQ_QAT_STEPS`.
//!
//! Regenerate with `cargo bench --bench table1_end_to_end`.

use mcu_mixq::coordinator::{self, deploy::render_rows, PipelineCfg};
use mcu_mixq::ops::Method;
use mcu_mixq::runtime::{ArtifactStore, Runtime};

fn env_steps(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn main() -> mcu_mixq::Result<()> {
    let store = ArtifactStore::open("artifacts")?;
    let rt = Runtime::cpu()?;
    println!("Table I — end-to-end performance comparison\n");

    for backbone in ["vgg_tiny", "mobilenet_tiny"] {
        let mut cfg = PipelineCfg::new(backbone);
        cfg.search.steps = env_steps("MCU_MIXQ_SEARCH_STEPS", 150);
        cfg.qat.steps = env_steps("MCU_MIXQ_QAT_STEPS", 250);

        let t0 = std::time::Instant::now();
        let report = coordinator::run_pipeline(&rt, &store, &cfg)?;
        println!(
            "{backbone}: searched w={:?} a={:?} (QAT acc {:.1}%)",
            report.searched_wbits,
            report.searched_abits,
            report.qat_eval_acc * 100.0
        );
        println!("{}", render_rows(backbone, &report.rows));
        for (m, s) in &report.speedups {
            println!("  MCU-MixQ speedup over {m}: {s:.2}x");
        }
        println!("  (pipeline wall-clock {:.0}s)\n", t0.elapsed().as_secs_f64());

        // Qualitative guards: who wins.
        let clocks = |m: Method| {
            report
                .rows
                .iter()
                .find(|r| r.method == m)
                .map(|r| r.clocks)
                .unwrap_or(u64::MAX)
        };
        let mixq = clocks(Method::RpSlbc);
        assert!(mixq < clocks(Method::CmixNn), "{backbone}: must beat CMix-NN");
        assert!(mixq < clocks(Method::WpcDdd), "{backbone}: must beat WPC&DDD");
        assert!(
            mixq < clocks(Method::TinyEngine),
            "{backbone}: must beat int8 TinyEngine"
        );
        // Memory ordering: planned arenas beat all-live library allocation.
        let peak = |m: Method| {
            report
                .rows
                .iter()
                .find(|r| r.method == m)
                .map(|r| r.peak_sram)
                .unwrap_or(usize::MAX)
        };
        assert!(
            peak(Method::RpSlbc) < peak(Method::CmixNn),
            "{backbone}: planned arena must beat library allocation"
        );
    }
    println!("(paper: 2.1x over CMix-NN, 1.4x over MCUNet/TinyEngine on average)");
    Ok(())
}
