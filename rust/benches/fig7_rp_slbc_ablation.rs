//! Fig. 7 — SLBC vs reordered-packing SLBC (RP-SLBC) latency ablation.
//!
//! Protocol (paper §V.B): integrate both kernels into the end-to-end
//! framework, run the two backbones at representative mixed-precision
//! configurations and compare whole-network latency; the reordering
//! (Theorem IV.1) merges segmentation work and buys up to ≈1.1×.
//!
//! Since the engine's compile/run split, each (backbone, bits, method)
//! triple compiles **one** `CompiledModel` artifact — quantization,
//! memory plan and the pre-packed kernel registers are built once and
//! reused across trials (cycle counts are geometry-determined, so
//! repeated runs on one artifact are cycle-exact; asserted below).
//!
//! Regenerate with `cargo bench --bench fig7_rp_slbc_ablation`.

use mcu_mixq::engine::CompiledModel;
use mcu_mixq::models::{mobilenet_tiny, vgg_tiny, ModelDesc};
use mcu_mixq::ops::Method;
use mcu_mixq::quant::BitConfig;
use mcu_mixq::util::bench::Table;
use mcu_mixq::util::prng::Rng;
use mcu_mixq::cycles_to_ms;

fn run_model(model: &ModelDesc, bits: u8, seed: u64) -> (Vec<(String, u64)>, Vec<(String, u64)>) {
    let mut rng = Rng::new(seed);
    let flat: Vec<f32> = (0..model.param_count).map(|_| rng.normal() * 0.15).collect();
    let cfg = BitConfig::uniform(model.num_layers(), bits);
    let img: Vec<f32> = (0..model.input_hw * model.input_hw * model.input_c)
        .map(|_| rng.f32())
        .collect();
    // One artifact per method, reused for every trial on this config.
    let slbc_art = CompiledModel::compile_unbounded(model, &flat, &cfg, Method::Slbc);
    let rp_art = CompiledModel::compile_unbounded(model, &flat, &cfg, Method::RpSlbc);
    let slbc = slbc_art.run(&img).unwrap();
    let rp = rp_art.run(&img).unwrap();
    // Artifact reuse is cycle-exact: a second trial on the same compiled
    // model must reproduce the per-layer numbers bit for bit.
    let again = slbc_art.run(&img).unwrap();
    assert_eq!(slbc.per_layer, again.per_layer, "artifact reuse must be cycle-exact");
    (slbc.per_layer, rp.per_layer)
}

fn main() {
    println!("Fig. 7 — latency: naive SLBC vs reordered-packing SLBC\n");
    for (model, bits) in [
        (vgg_tiny(10, 16), 4u8),
        (vgg_tiny(10, 16), 2u8),
        (mobilenet_tiny(2, 16), 4u8),
        (mobilenet_tiny(2, 16), 2u8),
    ] {
        let (slbc, rp) = run_model(&model, bits, 11 + bits as u64);
        let mut t = Table::new(vec!["layer", "SLBC cyc", "RP-SLBC cyc", "ratio"]);
        let (mut tot_s, mut tot_r) = (0u64, 0u64);
        for ((name, cs), (_, cr)) in slbc.iter().zip(&rp) {
            t.row(vec![
                name.clone(),
                format!("{cs}"),
                format!("{cr}"),
                format!("{:.3}x", *cs as f64 / *cr as f64),
            ]);
            tot_s += cs;
            tot_r += cr;
        }
        println!("{} @ uniform {}-bit:", model.name, bits);
        t.print();
        let ratio = tot_s as f64 / tot_r as f64;
        println!(
            "total: {} vs {} cycles ({:.2} vs {:.2} ms)  →  RP-SLBC speedup {:.3}x\n",
            tot_s,
            tot_r,
            cycles_to_ms(tot_s),
            cycles_to_ms(tot_r),
            ratio
        );
        assert!(
            ratio >= 1.0,
            "{} @{}b: reordering must not slow the network down",
            model.name,
            bits
        );
    }
    println!("(paper: up to ~1.1x from reordered packing; gain concentrates at low bits)");
}
