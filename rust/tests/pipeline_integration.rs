//! Whole-stack integration: coordinator loops over PJRT + engine deploy.
//!
//! Short-but-real runs of the search/QAT loops (training must make
//! progress) and the full deployment comparison, proving the three layers
//! compose. Step counts are kept small; the full-scale runs live in the
//! benches and `examples/deploy_vww.rs`.
//!
//! All tests here are `#[ignore]`d by default: they need the AOT
//! artifacts plus a real PJRT runtime (the offline workspace builds
//! against an xla stub). Run them with `cargo test -- --ignored` in a
//! full environment.

use mcu_mixq::coordinator::qat::QatCfg;
use mcu_mixq::coordinator::{
    deploy_all_methods, QatRunner, SearchCfg, SupernetSearch,
};
use mcu_mixq::nas::CostProxy;
use mcu_mixq::ops::Method;
use mcu_mixq::perf::PerfModel;
use mcu_mixq::runtime::{ArtifactStore, Runtime};

fn store() -> ArtifactStore {
    ArtifactStore::open(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .expect("artifacts/ missing — run `make artifacts`")
}

#[test]
#[ignore = "environment-bound: needs artifacts/ (make artifacts) and a real PJRT runtime; the offline build ships an xla stub"]
fn qat_loss_decreases_on_mobilenet() {
    let store = store();
    let rt = Runtime::cpu().unwrap();
    let arts = store.backbone("mobilenet_tiny").unwrap();
    let runner = QatRunner::new(&rt, &arts, 5).unwrap();
    let init = arts.load_init_params().unwrap();
    let cfg = mcu_mixq::quant::BitConfig::uniform(arts.model.num_layers(), 4);
    let qcfg = QatCfg {
        steps: 60,
        lr: 0.05,
        seed: 5,
        log_every: 5,
    };
    let out = runner.run(&init, &cfg, &qcfg).unwrap();
    let first = out.history.first().unwrap().loss;
    let last = out.history.last().unwrap().loss;
    assert!(
        last < first * 0.9,
        "QAT must reduce loss: {first} -> {last}"
    );
    // 2-class task: better than chance after 60 steps.
    assert!(out.eval_acc > 0.55, "eval acc {}", out.eval_acc);
    assert_eq!(out.params.len(), arts.model.param_count);
    assert!(out.params.iter().all(|p| p.is_finite()));
}

#[test]
#[ignore = "environment-bound: needs artifacts/ (make artifacts) and a real PJRT runtime; the offline build ships an xla stub"]
fn supernet_search_produces_valid_config_and_learns() {
    let store = store();
    let rt = Runtime::cpu().unwrap();
    let arts = store.backbone("mobilenet_tiny").unwrap();
    let pm = PerfModel::cortex_m7();
    let search = SupernetSearch::new(
        &rt,
        &arts,
        CostProxy::SimdAware(pm, Method::RpSlbc),
        7,
    )
    .unwrap();
    let scfg = SearchCfg {
        steps: 40,
        log_every: 5,
        ..SearchCfg::default()
    };
    let out = search.run(&scfg).unwrap();
    assert_eq!(out.config.num_layers(), arts.model.num_layers());
    for i in 0..out.config.num_layers() {
        assert!((2..=8).contains(&out.config.wbits[i]));
        assert!((2..=8).contains(&out.config.abits[i]));
    }
    // The complexity pressure must bite: average bits below the 8-bit cap.
    assert!(out.config.avg_wbits() < 7.0, "avg wbits {}", out.config.avg_wbits());
    // 2-class accuracy should beat chance by the end.
    let last = out.history.last().unwrap();
    assert!(last.acc > 0.6, "search acc {}", last.acc);
}

#[test]
#[ignore = "environment-bound: needs artifacts/ (make artifacts) and a real PJRT runtime; the offline build ships an xla stub"]
fn proxy_choice_changes_cost_table() {
    let store = store();
    let rt = Runtime::cpu().unwrap();
    let arts = store.backbone("vgg_tiny").unwrap();
    let pm = PerfModel::cortex_m7();
    let s_simd = SupernetSearch::new(&rt, &arts, CostProxy::SimdAware(pm, Method::RpSlbc), 1)
        .unwrap();
    let s_ed = SupernetSearch::new(&rt, &arts, CostProxy::EdMipsMacs, 1).unwrap();
    assert_ne!(
        s_simd.cost_table().data, s_ed.cost_table().data,
        "the two proxies must produce different complexity signals"
    );
}

#[test]
#[ignore = "environment-bound: needs artifacts/ (make artifacts) and a real PJRT runtime; the offline build ships an xla stub"]
fn deploy_all_methods_produces_consistent_table() {
    let store = store();
    let rt = Runtime::cpu().unwrap();
    let arts = store.backbone("mobilenet_tiny").unwrap();
    let model = arts.model.clone();
    let searched = mcu_mixq::quant::BitConfig {
        wbits: vec![4, 3, 4, 3, 4, 3, 4, 8],
        abits: vec![4, 4, 4, 4, 4, 4, 4, 8],
    };
    let params = arts.load_init_params().unwrap();
    let probe = mcu_mixq::datasets::generate(mcu_mixq::datasets::Task::SynthVww, 1, 16, 3);
    let qcfg = QatCfg {
        steps: 30,
        lr: 0.05,
        seed: 2,
        log_every: 10,
    };
    let methods = [
        Method::CmixNn,
        Method::WpcDdd,
        Method::TinyEngine,
        Method::RpSlbc,
    ];
    let target = mcu_mixq::target::Target::lookup("stm32f746").unwrap();
    let rows = deploy_all_methods(
        &rt, &arts, &model, &searched, &params, &methods, &qcfg, probe.image(0), target,
    )
    .unwrap();
    assert_eq!(rows.len(), 4);
    let row = |m: Method| rows.iter().find(|r| r.method == m).unwrap();

    // Table I orderings that must hold structurally:
    // 1. MCU-MixQ fastest.
    assert!(row(Method::RpSlbc).clocks < row(Method::CmixNn).clocks);
    assert!(row(Method::RpSlbc).clocks < row(Method::WpcDdd).clocks);
    assert!(row(Method::RpSlbc).clocks < row(Method::TinyEngine).clocks);
    // 2. Planned arenas (TinyEngine, MixQ) below library allocation.
    assert!(row(Method::RpSlbc).peak_sram < row(Method::CmixNn).peak_sram);
    assert!(row(Method::TinyEngine).peak_sram < row(Method::CmixNn).peak_sram);
    // 3. Sub-byte weights shrink MixQ's weight flash vs int8 TinyEngine,
    //    though codegen overhead narrows the gap (as in Table I, where
    //    TinyEngine-class flash is dominated by generated code).
    // 4. Everything fits the STM32F746.
    for r in &rows {
        assert!(r.peak_sram <= mcu_mixq::STM32F746_SRAM_BYTES);
        assert!(r.flash_bytes <= mcu_mixq::STM32F746_FLASH_BYTES);
        assert!(r.latency_ms > 0.0);
        assert!((0.0..=1.0).contains(&r.accuracy));
    }
}
