//! Acceptance tests for `mixq-check` (the static analyzer).
//!
//! Three pins:
//! 1. every model key the suite exercises reports **zero**
//!    Error-severity findings on both registry targets;
//! 2. a deliberately over-packed plan (field too narrow for
//!    taps × bitwidths) is rejected by the analyzer **and** by strict
//!    compile with the same rule id (`packing/lane-overflow`);
//! 3. the analyzer's worst-case lane bound is *exact*: it equals the
//!    brute-force maximum over all operand values for small configs —
//!    no false "safe" verdicts, and no over-tightness (a plan brute
//!    force shows safe is never called unsafe).

use mcu_mixq::analysis::{self, field_capacity, rules, worst_case_field_sum, Severity};
use mcu_mixq::engine::CompiledModel;
use mcu_mixq::models::{self, ModelDesc};
use mcu_mixq::ops::slbc::LayerKernel;
use mcu_mixq::ops::Method;
use mcu_mixq::quant::BitConfig;
use mcu_mixq::simd::poly::{conv1d_full_direct, PackSpec};
use mcu_mixq::target::Target;
use mcu_mixq::util::prng::Rng;

fn params_for(model: &ModelDesc, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..model.param_count).map(|_| rng.normal() * 0.1).collect()
}

fn compile(
    model: &ModelDesc,
    bits: u8,
    method: Method,
    target: &'static Target,
) -> CompiledModel {
    let params = params_for(model, 1000);
    let cfg = BitConfig::uniform(model.layers.len(), bits);
    CompiledModel::compile_for(model, &params, &cfg, method, target)
        .expect("suite-exercised config must compile")
}

/// Every (backbone, method, bits) combination the existing test suite
/// and benches exercise must come out of the analyzer clean.
#[test]
fn suite_model_keys_report_zero_errors() {
    let m7 = Target::lookup("stm32f746").unwrap();
    let grid: &[(Method, &[u8])] = &[
        (Method::Slbc, &[2, 4, 8]),
        (Method::RpSlbc, &[2, 4, 8]),
        (Method::CmixNn, &[2, 4, 8]),
        (Method::WpcDdd, &[2, 4, 8]),
        (Method::TinyEngine, &[8]),
        (Method::Naive, &[8]),
        (Method::Simd, &[8]),
    ];
    for model in [models::vgg_tiny(10, 16), models::mobilenet_tiny(2, 16)] {
        for (method, bits_list) in grid {
            for &bits in *bits_list {
                let cm = compile(&model, bits, *method, m7);
                let rep = analysis::analyze(&cm);
                assert_eq!(
                    rep.errors(),
                    0,
                    "{}/{}/w{bits}: {:?}",
                    model.name,
                    method.name(),
                    rep.error_rules()
                );
                if matches!(*method, Method::Slbc | Method::RpSlbc) {
                    assert!(!rep.lanes.is_empty(), "SLBC must produce lane audits");
                    assert!(rep.lanes.iter().all(|a| a.safe));
                }
            }
        }
    }

    // The canonical fig5/fig6 config must also clear the smaller M4.
    let m4 = Target::lookup("stm32f446").unwrap();
    for (model, method) in [
        (models::vgg_tiny(10, 16), Method::RpSlbc),
        (models::mobilenet_tiny(2, 16), Method::Slbc),
    ] {
        let rep = analysis::analyze(&compile(&model, 4, method, m4));
        assert_eq!(rep.errors(), 0, "{}: {:?}", model.name, rep.error_rules());
    }
}

/// Strict compilation is `compile_for` + the analyzer gate; clean
/// configs must pass it on both targets.
#[test]
fn strict_compile_accepts_clean_configs() {
    for tname in ["stm32f746", "stm32f446"] {
        let target = Target::lookup(tname).unwrap();
        let model = models::vgg_tiny(10, 16);
        let params = params_for(&model, 1000);
        let cfg = BitConfig::uniform(model.layers.len(), 4);
        CompiledModel::compile_for_strict(&model, &params, &cfg, Method::RpSlbc, target)
            .unwrap_or_else(|e| panic!("strict compile must accept a clean config: {e:#}"));
    }
}

/// The acceptance pin: plant a field too narrow for taps × bitwidths
/// and require BOTH the analyzer and the strict gate to reject it with
/// `packing/lane-overflow`.
#[test]
fn overpacked_plan_rejected_by_analyzer_and_strict_gate_with_same_rule() {
    let m7 = Target::lookup("stm32f746").unwrap();
    let model = models::vgg_tiny(10, 16);
    let mut cm = compile(&model, 4, Method::Slbc, m7);

    // Grab a packed conv kernel past layer 0 (layer 0 packs 8-bit
    // image inputs; inner layers run the configured 4 bits).
    let (idx, ck) = (1..cm.model.layers.len())
        .find_map(|i| match cm.kernels.layer(i) {
            Some(LayerKernel::Conv(ck)) => Some((i, ck.clone())),
            _ => None,
        })
        .expect("vgg has packed conv layers past layer 0");

    // Narrow the field to the activation width alone: capacity
    // 2^4 - 1 = 15 cannot hold even one worst-case term (15 * 15), let
    // alone min(G, K) of them — provably over-packed.
    let mut bad = ck;
    let narrow = bad.abits as u32;
    bad.plan.conv.spec.field = narrow;
    bad.plan.field = narrow;
    cm.kernels.set_layer(idx, Some(LayerKernel::Conv(bad)));

    let rep = analysis::analyze(&cm);
    let overflow: Vec<_> = rep
        .diagnostics
        .iter()
        .filter(|d| d.rule == rules::LANE_OVERFLOW)
        .collect();
    assert!(!overflow.is_empty(), "analyzer must flag the planted overflow");
    assert!(overflow.iter().all(|d| d.severity == Severity::Error));
    assert_eq!(overflow[0].layer, Some(idx));
    assert!(rep.error_rules().contains(&rules::LANE_OVERFLOW));

    // Strict gate: same artifact, same rule id in the rejection text.
    let err = cm.verify_strict().expect_err("strict gate must reject");
    let text = format!("{err:#}");
    assert!(
        text.contains(rules::LANE_OVERFLOW),
        "rejection must carry the rule id, got: {text}"
    );
}

/// Exhaustive brute force: the true per-field maximum of a packed
/// multiply over ALL operand tuples (mixed-radix enumeration).
fn brute_force_max_field(spec: &PackSpec) -> u128 {
    let g = spec.group as usize;
    let kt = spec.k_taps as usize;
    let xcard = 1u64 << spec.sx_bits;
    let kcard = 1u64 << spec.sk_bits;
    let mut x = vec![0u64; g];
    let mut k = vec![0u64; kt];
    let mut best = 0u128;
    loop {
        let peak = *conv1d_full_direct(&x, &k).iter().max().unwrap();
        best = best.max(peak as u128);
        // Increment (x ++ k) as one mixed-radix counter.
        let mut carried = true;
        for v in x.iter_mut() {
            if *v + 1 < xcard {
                *v += 1;
                carried = false;
                break;
            }
            *v = 0;
        }
        if carried {
            for v in k.iter_mut() {
                if *v + 1 < kcard {
                    *v += 1;
                    carried = false;
                    break;
                }
                *v = 0;
            }
        }
        if carried {
            return best;
        }
    }
}

/// Satellite pin, part 1: over small carriers (tiny groups) the
/// analyzer's bound EQUALS the exhaustive maximum — exact, so there can
/// be neither a false "safe" nor hidden over-tightness.
#[test]
fn lane_bound_is_exact_against_exhaustive_enumeration() {
    let mut checked = 0u32;
    for sx in 1..=3u32 {
        for sk in 1..=3u32 {
            for kt in 1..=4u32 {
                for rb in [8u32, 12, 16, 20] {
                    let Some(spec) = PackSpec::new(sx, sk, kt, rb) else { continue };
                    let combos = (1u128 << sx).pow(spec.group) * (1u128 << sk).pow(kt);
                    if combos > 300_000 {
                        continue;
                    }
                    let brute = brute_force_max_field(&spec);
                    let bound = worst_case_field_sum(sx, sk, kt, spec.group);
                    assert_eq!(bound, brute, "bound must be exact for {spec:?}");
                    // Planner-chosen fields are safe, confirmed by the oracle.
                    assert!(brute <= field_capacity(spec.field));
                    checked += 1;
                }
            }
        }
    }
    assert!(checked >= 20, "enumeration grid degenerated ({checked} specs)");
}

/// Satellite pin, part 2: for every candidate field width the
/// analyzer's safe/unsafe verdict matches the brute-force truth —
/// narrowed (over-packed) fields included.
#[test]
fn analyzer_verdict_matches_brute_force_for_every_field_width() {
    for sx in 1..=3u32 {
        for sk in 1..=3u32 {
            for kt in 1..=3u32 {
                for rb in [12u32, 16, 20] {
                    let Some(base) = PackSpec::new(sx, sk, kt, rb) else { continue };
                    let combos = (1u128 << sx).pow(base.group) * (1u128 << sk).pow(kt);
                    if combos > 300_000 {
                        continue;
                    }
                    // The true max depends only on (bits, taps, group).
                    let brute = brute_force_max_field(&base);
                    for field in 1..=base.field {
                        let analyzer_safe =
                            worst_case_field_sum(sx, sk, kt, base.group)
                                <= field_capacity(field);
                        let truly_safe = brute <= field_capacity(field);
                        assert_eq!(
                            analyzer_safe, truly_safe,
                            "verdict diverges at field={field} for {base:?}"
                        );
                    }
                }
            }
        }
    }
}

/// Satellite pin, part 3: up to 4-bit operands and 8 taps (the issue's
/// envelope) the bound is attained by all-max operands — achievability
/// on the big grid where full enumeration is too large.
#[test]
fn lane_bound_attained_by_all_max_operands_up_to_4bit_8tap() {
    for sx in 1..=4u32 {
        for sk in 1..=4u32 {
            for kt in 1..=8u32 {
                for rb in [16u32, 24, 32, 48, 63, 64] {
                    let Some(spec) = PackSpec::new(sx, sk, kt, rb) else { continue };
                    let x = vec![(1u64 << sx) - 1; spec.group as usize];
                    let k = vec![(1u64 << sk) - 1; kt as usize];
                    let peak = *conv1d_full_direct(&x, &k).iter().max().unwrap() as u128;
                    assert_eq!(
                        peak,
                        worst_case_field_sum(sx, sk, kt, spec.group),
                        "all-max operands must attain the bound for {spec:?}"
                    );
                    assert!(peak <= field_capacity(spec.field));
                }
            }
        }
    }
}

/// The machine-readable contract the CI trend artifact greps for.
#[test]
fn check_json_carries_schema_keys() {
    let m7 = Target::lookup("stm32f746").unwrap();
    let cm = compile(&models::vgg_tiny(10, 16), 4, Method::RpSlbc, m7);
    let js = analysis::analyze(&cm).to_json().to_string_compact();
    for key in ["\"rule\"", "\"severity\"", "\"sram_peak_bytes\"", "\"diagnostics\""] {
        assert!(js.contains(key), "missing {key} in {js}");
    }
}
