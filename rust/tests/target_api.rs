//! Unified `Target` API integration tests.
//!
//! Three contracts:
//!
//! 1. **Delegation** — the legacy constructors (`Machine::stm32f746`,
//!    `Memory::stm32f746`, `DeviceCfg::stm32f746`) are one-line
//!    delegations to the `Target` registry and agree with it exactly.
//! 2. **Pricing pin** — `Target`-routed `perf::predict` pricing
//!    (`PredictedCost::cycles_on`) matches the pre-refactor path
//!    (folding the predicted counter through `CycleModel::cortex_m7`)
//!    bit-for-bit on the fig5/fig6 operand sets.
//! 3. **Fleet spec round-trip** — `Target::parse_fleet` ↔
//!    `Target::fleet_spec`, with parse errors naming the offending
//!    token and the registered target names.

use mcu_mixq::mcu::{CycleModel, Machine, Memory};
use mcu_mixq::models::vgg_tiny;
use mcu_mixq::ops::Method;
use mcu_mixq::perf::{predict_layer, predict_model, PerfModel};
use mcu_mixq::quant::BitConfig;
use mcu_mixq::serve::DeviceCfg;
use mcu_mixq::target::{DeviceClass, Target};

#[test]
fn machine_and_memory_constructors_delegate_to_the_registry() {
    let m7 = Target::lookup("stm32f746").unwrap();
    let m4 = Target::lookup("stm32f446").unwrap();

    let machine = Machine::stm32f746();
    assert_eq!(machine.mem.sram_len(), m7.sram_bytes);
    assert_eq!(machine.mem.flash_len(), m7.flash_bytes);
    assert_eq!(machine.model, m7.cycle_model);

    let machine = Machine::stm32f446();
    assert_eq!(machine.mem.sram_len(), m4.sram_bytes);
    assert_eq!(machine.mem.flash_len(), m4.flash_bytes);
    assert_eq!(machine.model, m4.cycle_model);

    let mem = Memory::stm32f746();
    assert_eq!(mem.sram_len(), m7.sram_bytes);
    assert_eq!(mem.flash_len(), m7.flash_bytes);
    let mem = Memory::for_target(m4);
    assert_eq!(mem.sram_len(), m4.sram_bytes);

    // The serving DeviceCfg is an alias of Target: same values, same
    // registry.
    assert_eq!(DeviceCfg::stm32f746(), *m7);
    assert_eq!(DeviceCfg::stm32f446(), *m4);
    assert_eq!(DeviceCfg::parse_class("m4"), Some(*m4));
    assert_eq!(DeviceCfg::parse_class("m33"), None);

    // And the registry models match the mcu-layer tables.
    assert_eq!(m7.cycle_model, CycleModel::cortex_m7());
    assert_eq!(m4.cycle_model, CycleModel::cortex_m4());
    assert_eq!(PerfModel::for_target(m7), PerfModel::cortex_m7());
}

/// Fig. 5 operand set: the VGG-Tiny conv3 layer at every bitwidth 2–8
/// under naive / plain-SIMD / SLBC. Target-routed pricing must equal
/// the pre-refactor `counter.cycles(&CycleModel::cortex_m7())` path
/// exactly.
#[test]
fn target_routed_predict_matches_prerefactor_cycles_on_fig5_set() {
    let m7 = Target::lookup("stm32f746").unwrap();
    let legacy = CycleModel::cortex_m7();
    let mut layer = vgg_tiny(10, 16).layers[2].clone();
    layer.macs = layer.compute_macs();
    for bits in 2..=8u8 {
        for method in [Method::Naive, Method::Simd, Method::Slbc] {
            let p = predict_layer(&layer, method, bits, bits);
            assert_eq!(
                p.cycles_on(m7),
                p.counter.cycles(&legacy),
                "{} at {bits} bits",
                method.name()
            );
            assert!(p.cycles_on(m7) > 0);
            assert!(p.joules_on(m7) > 0.0);
        }
    }
}

/// Fig. 6 operand set: the (wbits, abits) grid over {2,4,8} for
/// CMix-NN vs SLBC — same bit-for-bit pin, plus the M4-routed pricing
/// agreeing with the M4 cycle table.
#[test]
fn target_routed_predict_matches_prerefactor_cycles_on_fig6_grid() {
    let m7 = Target::lookup("stm32f746").unwrap();
    let m4 = Target::lookup("stm32f446").unwrap();
    let legacy_m7 = CycleModel::cortex_m7();
    let legacy_m4 = CycleModel::cortex_m4();
    let mut layer = vgg_tiny(10, 16).layers[2].clone();
    layer.macs = layer.compute_macs();
    for &w in &[2u8, 4, 8] {
        for &a in &[2u8, 4, 8] {
            for method in [Method::CmixNn, Method::Slbc] {
                let p = predict_layer(&layer, method, w, a);
                assert_eq!(p.cycles_on(m7), p.counter.cycles(&legacy_m7), "{} w{w}a{a}", method.name());
                assert_eq!(p.cycles_on(m4), p.counter.cycles(&legacy_m4), "{} w{w}a{a}", method.name());
            }
        }
    }
}

#[test]
fn target_routed_model_prediction_is_the_layer_sum_in_both_units() {
    let m7 = Target::lookup("m7").unwrap();
    let m4 = Target::lookup("m4").unwrap();
    let model = vgg_tiny(10, 16);
    let cfg = BitConfig::uniform(model.num_layers(), 4);
    let whole = predict_model(&model, Method::RpSlbc, &cfg);
    let cycle_sum: u64 = model
        .layers
        .iter()
        .map(|l| predict_layer(l, Method::RpSlbc, 4, 4).cycles_on(m7))
        .sum();
    assert_eq!(whole.cycles_on(m7), cycle_sum);
    // Energy pricing is target-specific: identical predicted work costs
    // fewer joules on the M4 (per-class dominance), more cycles never
    // fewer, and both units are positive.
    assert!(whole.joules_on(m4) < whole.joules_on(m7));
    assert!(whole.cycles_on(m4) >= whole.cycles_on(m7));
}

#[test]
fn fleet_specs_round_trip_and_errors_are_actionable() {
    let fleet = Target::parse_fleet("m7:2,m4:2").unwrap();
    assert_eq!(fleet.len(), 4);
    assert_eq!(fleet[0].class, DeviceClass::M7);
    assert_eq!(fleet[3].class, DeviceClass::M4);
    assert_eq!(Target::fleet_spec(&fleet), "m7:2,m4:2");
    assert_eq!(Target::parse_fleet(&Target::fleet_spec(&fleet)).unwrap(), fleet);

    let err = Target::parse_fleet("m7:2,riscv:3").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("riscv"), "offending token: {msg}");
    assert!(msg.contains("stm32f746") && msg.contains("stm32f446"), "known names: {msg}");
}
