//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These need `artifacts/` (produced by `make artifacts`); they verify the
//! Python→Rust interchange: manifest geometry equals the Rust model zoo,
//! every HLO program compiles and runs, and the Layer-1 Pallas kernel
//! agrees with the Rust packed-arithmetic implementation.
//!
//! All tests here are `#[ignore]`d by default: they need the AOT
//! artifacts plus a real PJRT runtime (the offline workspace builds
//! against an xla stub). Run them with `cargo test -- --ignored` in a
//! full environment.

use mcu_mixq::models;
use mcu_mixq::runtime::{lit, ArtifactStore, Runtime};
use mcu_mixq::simd::poly;
use mcu_mixq::util::prng::Rng;

fn store() -> ArtifactStore {
    ArtifactStore::open(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .expect("artifacts/ missing — run `make artifacts`")
}

#[test]
#[ignore = "environment-bound: needs artifacts/ (make artifacts) and a real PJRT runtime; the offline build ships an xla stub"]
fn manifest_matches_rust_model_zoo() {
    let store = store();
    for name in ["vgg_tiny", "mobilenet_tiny"] {
        let arts = store.backbone(name).unwrap();
        let rust_model = models::by_name(name).unwrap();
        assert_eq!(arts.model.num_layers(), rust_model.num_layers(), "{name}");
        assert_eq!(arts.model.param_count, rust_model.param_count, "{name}");
        for (a, b) in arts.model.layers.iter().zip(&rust_model.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kind, b.kind, "{name}:{}", a.name);
            assert_eq!(a.cin, b.cin, "{name}:{}", a.name);
            assert_eq!(a.cout, b.cout, "{name}:{}", a.name);
            assert_eq!(a.w_offset, b.w_offset, "{name}:{}", a.name);
            assert_eq!(a.w_size, b.w_size, "{name}:{}", a.name);
            assert_eq!(a.macs, b.macs, "{name}:{}", a.name);
        }
    }
}

#[test]
#[ignore = "environment-bound: needs artifacts/ (make artifacts) and a real PJRT runtime; the offline build ships an xla stub"]
fn init_params_load_and_have_sane_stats() {
    let store = store();
    for name in ["vgg_tiny", "mobilenet_tiny"] {
        let arts = store.backbone(name).unwrap();
        let p = arts.load_init_params().unwrap();
        assert_eq!(p.len(), arts.model.param_count);
        let mean = p.iter().sum::<f32>() / p.len() as f32;
        let var = p.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / p.len() as f32;
        assert!(mean.abs() < 0.05, "{name}: mean {mean}");
        assert!(var > 1e-4 && var < 1.0, "{name}: var {var}");
        assert!(p.iter().all(|x| x.is_finite()), "{name}: non-finite init");
    }
}

#[test]
#[ignore = "environment-bound: needs artifacts/ (make artifacts) and a real PJRT runtime; the offline build ships an xla stub"]
fn all_programs_compile() {
    let store = store();
    let rt = Runtime::cpu().unwrap();
    for name in ["vgg_tiny", "mobilenet_tiny"] {
        let arts = store.backbone(name).unwrap();
        let progs = arts.load_programs(&rt).unwrap();
        assert!(progs.qat_step.compile_time_s > 0.0);
        assert!(progs.eval.compile_time_s > 0.0);
        assert!(progs.infer.compile_time_s > 0.0);
        assert!(progs.supernet_step.compile_time_s > 0.0);
    }
}

#[test]
#[ignore = "environment-bound: needs artifacts/ (make artifacts) and a real PJRT runtime; the offline build ships an xla stub"]
fn infer_program_runs_and_returns_logits() {
    let store = store();
    let rt = Runtime::cpu().unwrap();
    let arts = store.backbone("vgg_tiny").unwrap();
    let prog = rt.load_program(&arts.infer).unwrap();
    let params = lit::f32_vec(&arts.load_init_params().unwrap());
    let hw = arts.model.input_hw;
    let img = vec![0.5f32; hw * hw * arts.model.input_c];
    let x = lit::f32_tensor(&img, &[1, hw as i64, hw as i64, 3]).unwrap();
    let wb = lit::f32_vec(&vec![8.0f32; arts.model.num_layers()]);
    let ab = lit::f32_vec(&vec![8.0f32; arts.model.num_layers()]);
    let outs = prog.run(&[&params, &x, &wb, &ab]).unwrap();
    let logits = lit::to_f32_vec(&outs[0]).unwrap();
    assert_eq!(logits.len(), arts.model.num_classes);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
#[ignore = "environment-bound: needs artifacts/ (make artifacts) and a real PJRT runtime; the offline build ships an xla stub"]
fn infer_bitwidth_tensors_change_logits() {
    // The runtime-bitwidth design: one artifact serves every quantization
    // config, and the config actually matters.
    let store = store();
    let rt = Runtime::cpu().unwrap();
    let arts = store.backbone("vgg_tiny").unwrap();
    let prog = rt.load_program(&arts.infer).unwrap();
    let params = lit::f32_vec(&arts.load_init_params().unwrap());
    let hw = arts.model.input_hw;
    let mut rng = Rng::new(3);
    let img: Vec<f32> = (0..hw * hw * 3).map(|_| rng.f32()).collect();
    let x = lit::f32_tensor(&img, &[1, hw as i64, hw as i64, 3]).unwrap();
    let l = arts.model.num_layers();
    let run_at = |bits: f32| {
        let wb = lit::f32_vec(&vec![bits; l]);
        let ab = lit::f32_vec(&vec![bits; l]);
        let outs = prog.run(&[&params, &x, &wb, &ab]).unwrap();
        lit::to_f32_vec(&outs[0]).unwrap()
    };
    let l8 = run_at(8.0);
    let l2 = run_at(2.0);
    assert_ne!(l8, l2, "bitwidth tensors must affect the computation");
}

#[test]
#[ignore = "environment-bound: needs artifacts/ (make artifacts) and a real PJRT runtime; the offline build ships an xla stub"]
fn slbc_demo_kernel_matches_rust_packing() {
    // Layer-1 (Pallas, via HLO) vs Layer-3 (Rust simd::poly): the same
    // packed-arithmetic convolution, two implementations, one answer.
    let store = store();
    let rt = Runtime::cpu().unwrap();
    let demo = store.slbc_demo().unwrap();
    let prog = rt.load_program(&demo.path).unwrap();
    for seed in [1u64, 7, 42] {
        let mut rng = Rng::new(seed);
        let x: Vec<i64> = (0..demo.n).map(|_| rng.below(1 << demo.sx_bits) as i64).collect();
        let k: Vec<i64> = (0..demo.k).map(|_| rng.below(1 << demo.sk_bits) as i64).collect();
        let outs = prog.run(&[lit::i64_vec(&x), lit::i64_vec(&k)]).unwrap();
        let got = lit::to_i64_vec(&outs[0]).unwrap();
        let xu: Vec<u64> = x.iter().map(|&v| v as u64).collect();
        let ku: Vec<u64> = k.iter().map(|&v| v as u64).collect();
        let direct: Vec<i64> = poly::conv1d_full_direct(&xu, &ku)
            .iter()
            .map(|&v| v as i64)
            .collect();
        let packed: Vec<i64> = poly::conv1d_full_packed(&xu, &ku, demo.sx_bits, demo.sk_bits)
            .iter()
            .map(|&v| v as i64)
            .collect();
        assert_eq!(got, direct, "seed {seed}: HLO vs direct");
        assert_eq!(got, packed, "seed {seed}: HLO vs rust packed");
    }
}

#[test]
#[ignore = "environment-bound: needs artifacts/ (make artifacts) and a real PJRT runtime; the offline build ships an xla stub"]
fn eval_program_accuracy_at_chance_for_init() {
    // Untrained params ⇒ accuracy ≈ chance on the 10-class task.
    let store = store();
    let rt = Runtime::cpu().unwrap();
    let arts = store.backbone("vgg_tiny").unwrap();
    let prog = rt.load_program(&arts.eval).unwrap();
    let params = lit::f32_vec(&arts.load_init_params().unwrap());
    let batch = mcu_mixq::datasets::generate(
        mcu_mixq::datasets::Task::SynthCifar,
        arts.eval_batch,
        arts.model.input_hw,
        99,
    );
    let x = lit::f32_tensor(
        &batch.images,
        &[
            arts.eval_batch as i64,
            arts.model.input_hw as i64,
            arts.model.input_hw as i64,
            3,
        ],
    )
    .unwrap();
    let y = lit::i32_vec(&batch.labels);
    let l = arts.model.num_layers();
    let wb = lit::f32_vec(&vec![8.0f32; l]);
    let ab = lit::f32_vec(&vec![8.0f32; l]);
    let outs = prog.run_n(&[&params, &x, &y, &wb, &ab], 2).unwrap();
    let loss = lit::to_f32_scalar(&outs[0]).unwrap();
    let acc = lit::to_f32_scalar(&outs[1]).unwrap();
    assert!(loss > 1.5 && loss < 4.0, "init loss {loss}");
    assert!(acc < 0.35, "init acc {acc} should be near chance");
}
