//! Golden suite for the rolling-row SLBC pipeline.
//!
//! 1. **Bit-exactness** against the direct (naive) oracle for every
//!    `(wbits, abits)` pair in 2..=8, across Conv / DwConv / Dense, both
//!    packing orders, on odd widths that exercise the ring-buffer
//!    wraparound and partial packing groups.
//! 2. **Counter equivalence**: modeled instruction histograms (and thus
//!    cycle totals) of the operators must match the analytic predictor
//!    term by term on a fixed layer set — the regression pin for the
//!    rolling-row charging rules (row work amortized across output rows,
//!    depthwise charged per channel).
//! 3. **Cached = uncached**: the `KernelCache` path must be bit- and
//!    cycle-identical to on-the-fly packing.
//!
//! Pure Rust — needs neither `artifacts/` nor a PJRT runtime.

use mcu_mixq::mcu::{Counter, CycleModel};
use mcu_mixq::models::{vgg_tiny, LayerKind, LayerSpec};
use mcu_mixq::ops::Method;
use mcu_mixq::ops::{common, slbc};
use mcu_mixq::perf::predict_layer;

fn layer(kind: LayerKind, h: usize, cin: usize, cout: usize, k: usize) -> LayerSpec {
    let mut l = vgg_tiny(10, 16).layers[0].clone();
    l.kind = kind;
    l.in_h = h;
    l.in_w = h;
    l.out_h = h;
    l.out_w = h;
    l.cin = cin;
    l.cout = cout;
    l.k = k;
    l.macs = l.compute_macs();
    l
}

fn rand_io(l: &LayerSpec, abits: u8, wbits: u8, seed: u64) -> (Vec<u32>, Vec<i32>) {
    common::rand_layer_operands(l, wbits, abits, seed)
}

fn oracle(x: &[u32], w: &[i32], l: &LayerSpec) -> Vec<i64> {
    match l.kind {
        LayerKind::Conv => common::direct_conv2d(x, w, l),
        LayerKind::DwConv => common::direct_dwconv2d(x, w, l),
        LayerKind::Dense => common::direct_dense(x, w, l),
    }
}

#[test]
fn golden_bit_exactness_full_bitwidth_grid() {
    // Odd spatial width (7) exercises partial packing groups at every row
    // end; k=3 rolls the ring through all three phases.
    for kind in [LayerKind::Conv, LayerKind::DwConv, LayerKind::Dense] {
        let l = match kind {
            LayerKind::Conv => layer(kind, 7, 2, 3, 3),
            LayerKind::DwConv => layer(kind, 7, 3, 3, 3),
            LayerKind::Dense => layer(kind, 1, 19, 5, 1),
        };
        for wb in 2..=8u8 {
            for ab in 2..=8u8 {
                let (x, w) = rand_io(&l, ab, wb, 7000 + wb as u64 * 16 + ab as u64);
                let want = oracle(&x, &w, &l);
                for rp in [false, true] {
                    let mut ctr = Counter::new();
                    let got = slbc::run_layer(&x, &w, &l, wb, ab, rp, &mut ctr);
                    assert_eq!(got, want, "{kind:?} w{wb}a{ab} rp={rp}");
                    assert!(ctr.instructions() > 0);
                }
            }
        }
    }
}

#[test]
fn golden_ring_wraparound_widths() {
    // Widths around the packing group boundaries (the ring slots wrap at
    // (iy + pad) % k while the packer straddles partial groups).
    for h in [3usize, 5, 7, 9, 11, 13] {
        for rp in [false, true] {
            let l = layer(LayerKind::Conv, h, 3, 2, 3);
            let (x, w) = rand_io(&l, 5, 3, 8000 + h as u64);
            let want = common::direct_conv2d(&x, &w, &l);
            let mut ctr = Counter::new();
            let got = slbc::run_layer(&x, &w, &l, 3, 5, rp, &mut ctr);
            assert_eq!(got, want, "h={h} rp={rp}");
        }
    }
}

/// The fixed layer set of the counter-equivalence pin: representative
/// shapes of both backbone families (regular conv, depthwise, pointwise,
/// dense) at sizes small enough to run the whole grid quickly.
fn pinned_layers() -> Vec<LayerSpec> {
    vec![
        layer(LayerKind::Conv, 8, 3, 4, 3),
        layer(LayerKind::Conv, 6, 4, 4, 1),
        layer(LayerKind::DwConv, 8, 6, 6, 3),
        layer(LayerKind::Dense, 1, 48, 10, 1),
    ]
}

#[test]
fn counter_equivalence_pins_cycle_totals() {
    // predict.rs mirrors the rolling-row charging term by term, from
    // geometry alone. Any change to either side breaks this pin — which
    // is the point: modeled cycle totals cannot drift silently.
    let cm = CycleModel::cortex_m7();
    for l in pinned_layers() {
        for method in [Method::Slbc, Method::RpSlbc] {
            for (wb, ab) in [(2u8, 2u8), (4, 4), (8, 8), (3, 5), (4, 8)] {
                let (x, w) = rand_io(&l, ab, wb, 9000 + wb as u64 * 8 + ab as u64);
                let mut measured = Counter::new();
                method.run_layer(&x, &w, &l, wb, ab, &mut measured);
                let predicted = predict_layer(&l, method, wb, ab);
                assert_eq!(
                    predicted.counter,
                    measured,
                    "{} {} w{wb}a{ab}: histogram drift",
                    l.name,
                    method.name()
                );
                assert_eq!(
                    predicted.counter.cycles(&cm),
                    measured.cycles(&cm),
                    "{} {} w{wb}a{ab}: cycle drift",
                    l.name,
                    method.name()
                );
            }
        }
    }
}

#[test]
fn cached_kernel_bit_and_cycle_identical_to_uncached() {
    for l in pinned_layers() {
        for rp in [false, true] {
            let (wb, ab) = (4u8, 4u8);
            let (x, w) = rand_io(&l, ab, wb, 4242);
            let kern = slbc::LayerKernel::build(&w, &l, wb, ab, rp);
            let mut c_cached = Counter::new();
            let cached = slbc::run_layer_cached(&x, &l, &kern, &mut c_cached);
            let mut c_fresh = Counter::new();
            let fresh = slbc::run_layer(&x, &w, &l, wb, ab, rp, &mut c_fresh);
            assert_eq!(cached, fresh, "{} rp={rp}", l.name);
            assert_eq!(c_cached, c_fresh, "{} rp={rp}: charging drift", l.name);
        }
    }
}

#[test]
fn depthwise_charging_counts_per_channel_rows() {
    // The depthwise fix: row work scales with the channel count (each
    // channel's rows are fetched/packed once), where the legacy operator
    // charged only the channel-0 prefetch regardless of cout.
    let narrow = layer(LayerKind::DwConv, 8, 4, 4, 3);
    let wide = layer(LayerKind::DwConv, 8, 16, 16, 3);
    let (xn, wn) = rand_io(&narrow, 4, 4, 1);
    let (xw, ww) = rand_io(&wide, 4, 4, 2);
    let mut c_narrow = Counter::new();
    slbc::run_layer(&xn, &wn, &narrow, 4, 4, false, &mut c_narrow);
    let mut c_wide = Counter::new();
    slbc::run_layer(&xw, &ww, &wide, 4, 4, false, &mut c_wide);
    // 4x the channels ⇒ 4x the charged row loads (row geometry is equal).
    assert_eq!(c_wide.load, 4 * c_narrow.load, "row loads must scale with channels");

    // And the legacy operator undercharged: same wide layer, legacy
    // charges strictly fewer loads than the honest per-channel pipeline.
    let mut c_legacy = Counter::new();
    slbc::legacy::run_layer(&xw, &ww, &wide, 4, 4, false, &mut c_legacy);
    assert!(
        c_wide.load > c_legacy.load,
        "depthwise fix must charge the per-channel rows ({} vs legacy {})",
        c_wide.load,
        c_legacy.load
    );
}
