//! Cross-module integration of the Eq. 12 performance model: predictions
//! vs whole-network measurements, and the co-design property that the
//! SIMD-aware cost signal actually tracks deployed latency better than
//! the EdMIPS MAC proxy.

use mcu_mixq::engine;
use mcu_mixq::mcu::CycleModel;
use mcu_mixq::models::{mobilenet_tiny, vgg_tiny};
use mcu_mixq::ops::Method;
use mcu_mixq::perf::{mac_proxy, predict_model, PerfModel};
use mcu_mixq::quant::{quantize_model, BitConfig};
use mcu_mixq::util::prng::Rng;

/// Measured whole-network kernel cycles (conv/dense only — the perf model
/// predicts operator cost, not pooling/requant glue).
fn measured_kernel_cycles(model: &mcu_mixq::models::ModelDesc, method: Method, cfg: &BitConfig) -> u64 {
    let cm = CycleModel::cortex_m7();
    let mut rng = Rng::new(1);
    let mut total = 0u64;
    for (i, l) in model.layers.iter().enumerate() {
        let (wb, ab) = (cfg.wbits[i], cfg.abits[i]);
        let x: Vec<u32> = (0..l.in_elems()).map(|_| rng.below(1 << ab) as u32).collect();
        let lim = (1i64 << (wb - 1)) - 1;
        let w: Vec<i32> = (0..l.w_size)
            .map(|_| (rng.below(2 * lim as u64 + 1) as i64 - lim) as i32)
            .collect();
        let mut ctr = mcu_mixq::mcu::Counter::new();
        method.run_layer(&x, &w, l, wb, ab, &mut ctr);
        total += ctr.cycles(&cm);
    }
    total
}

#[test]
fn whole_network_prediction_matches_measurement() {
    // predict.rs mirrors charging exactly → identical histograms per layer
    // → identical cycle totals for the whole network.
    let cm = CycleModel::cortex_m7();
    for model in [vgg_tiny(10, 16), mobilenet_tiny(2, 16)] {
        for bits in [2u8, 4, 7] {
            let cfg = BitConfig::uniform(model.num_layers(), bits);
            for method in [Method::Slbc, Method::RpSlbc, Method::CmixNn] {
                if !method.supports(bits, bits) {
                    continue;
                }
                let predicted = predict_model(&model, method, &cfg).counter.cycles(&cm);
                let measured = measured_kernel_cycles(&model, method, &cfg);
                assert_eq!(
                    predicted, measured,
                    "{} {} @{}bit",
                    model.name,
                    method.name(),
                    bits
                );
            }
        }
    }
}

#[test]
fn eq12_ranks_configs_like_the_simulator() {
    // The co-design claim: for config pairs where the MAC proxy is blind
    // (equal MAC-bit products), Eq. 12 and the simulator agree on which
    // one is faster.
    let model = vgg_tiny(10, 16);
    let n = model.num_layers();
    let pm = PerfModel::cortex_m7();
    // (2,8) and (4,4) have identical wb·ab; packing costs differ.
    let cfg_a = BitConfig {
        wbits: vec![2; n],
        abits: vec![8; n],
    };
    let cfg_b = BitConfig::uniform(n, 4);
    let mac_a: f64 = model.layers.iter().map(|l| mac_proxy(l, 2, 8)).sum();
    let mac_b: f64 = model.layers.iter().map(|l| mac_proxy(l, 4, 4)).sum();
    assert!((mac_a - mac_b).abs() < 1e-6, "MAC proxy must tie");

    let eq12_a = pm.model_complexity(&model, Method::RpSlbc, &cfg_a);
    let eq12_b = pm.model_complexity(&model, Method::RpSlbc, &cfg_b);
    let meas_a = measured_kernel_cycles(&model, Method::RpSlbc, &cfg_a);
    let meas_b = measured_kernel_cycles(&model, Method::RpSlbc, &cfg_b);
    assert_ne!(meas_a, meas_b, "simulator must distinguish the pair");
    assert_eq!(
        eq12_a < eq12_b,
        meas_a < meas_b,
        "Eq.12 ranking must match the simulator: eq12 ({eq12_a:.0} vs {eq12_b:.0}), \
         measured ({meas_a} vs {meas_b})"
    );
}

#[test]
fn deployed_latency_tracks_eq12_across_uniform_bits() {
    // Spearman-style check over uniform configs 2..8: more Eq.12 cost ⇒
    // more engine cycles (monotone agreement).
    let model = vgg_tiny(10, 16);
    let pm = PerfModel::cortex_m7();
    let mut rng = Rng::new(9);
    let flat: Vec<f32> = (0..model.param_count).map(|_| rng.normal() * 0.1).collect();
    let img: Vec<f32> = (0..16 * 16 * 3).map(|_| rng.f32()).collect();
    let cm = CycleModel::cortex_m7();
    let mut pairs = Vec::new();
    for bits in 2..=8u8 {
        let cfg = BitConfig::uniform(model.num_layers(), bits);
        let q = quantize_model(&model, &flat, &cfg);
        let r = engine::infer(&model, &q, &cfg, Method::RpSlbc, &img, &cm).unwrap();
        let c = pm.model_complexity(&model, Method::RpSlbc, &cfg);
        pairs.push((c, r.cycles));
    }
    for w in pairs.windows(2) {
        assert!(
            w[0].0 < w[1].0 && w[0].1 < w[1].1,
            "both cost and cycles must grow with bits: {pairs:?}"
        );
    }
}
