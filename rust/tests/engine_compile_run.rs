//! Compile/run split equivalence: the `CompiledModel` path must be
//! bit-exact and cycle-exact with the single-shot `deploy()` wrapper
//! (which itself is now compile-then-run), across deployment methods and
//! bit configurations, and deterministic across repeated runs on one
//! artifact.
//!
//! Pure Rust — needs neither `artifacts/` nor a PJRT runtime.

use mcu_mixq::engine::{deploy, CompiledModel};
use mcu_mixq::models::vgg_tiny;
use mcu_mixq::ops::Method;
use mcu_mixq::quant::BitConfig;
use mcu_mixq::util::prng::Rng;

fn fake_params(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() * 0.15).collect()
}

fn probe_image(hw: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..hw * hw * 3).map(|_| rng.f32()).collect()
}

#[test]
fn compiled_run_matches_deploy_across_methods_and_bits() {
    let model = vgg_tiny(10, 16);
    let params = fake_params(model.param_count, 31);
    let img = probe_image(16, 77);

    for method in [Method::RpSlbc, Method::CmixNn, Method::TinyEngine] {
        for bits in [4u8, 8] {
            if !method.supports(bits, bits) {
                continue; // TinyEngine kernels are int8-only
            }
            let cfg = BitConfig::uniform(model.num_layers(), bits);
            let via_deploy = deploy(&model, &params, &cfg, method, &img).unwrap();
            let compiled = CompiledModel::compile(&model, &params, &cfg, method).unwrap();
            let via_run = compiled.report(&img).unwrap();

            let ctx = format!("{} @ {bits}bit", method.name());
            assert_eq!(via_deploy.cycles, via_run.cycles, "{ctx}: cycles");
            assert_eq!(via_deploy.per_layer, via_run.per_layer, "{ctx}: per-layer");
            assert_eq!(via_deploy.peak_sram, via_run.peak_sram, "{ctx}: peak SRAM");
            assert_eq!(via_deploy.flash_bytes, via_run.flash_bytes, "{ctx}: flash");
            assert_eq!(via_deploy.backbone, via_run.backbone, "{ctx}: backbone");
            assert_eq!(via_deploy.method, via_run.method, "{ctx}: method");
            assert_eq!(via_deploy.config, via_run.config, "{ctx}: config");
            assert!(
                (via_deploy.latency_ms - via_run.latency_ms).abs() < 1e-12,
                "{ctx}: latency"
            );
        }
    }
}

#[test]
fn compiled_logits_match_fresh_inference() {
    // Beyond report fields: the actual logits through the cached artifact
    // equal a from-scratch inference on freshly quantized weights.
    let model = vgg_tiny(10, 16);
    let params = fake_params(model.param_count, 5);
    let img = probe_image(16, 9);
    for method in [Method::RpSlbc, Method::CmixNn] {
        let cfg = BitConfig::uniform(model.num_layers(), 4);
        let compiled = CompiledModel::compile(&model, &params, &cfg, method).unwrap();
        let cached = compiled.run(&img).unwrap();
        let fresh = mcu_mixq::engine::infer(
            &model,
            &mcu_mixq::quant::quantize_model(&model, &params, &cfg),
            &cfg,
            method,
            &img,
            &mcu_mixq::mcu::CycleModel::cortex_m7(),
        )
        .unwrap();
        assert_eq!(cached.logits, fresh.logits, "{}", method.name());
        assert_eq!(cached.pred, fresh.pred, "{}", method.name());
        assert_eq!(cached.cycles, fresh.cycles, "{}", method.name());
    }
}

#[test]
fn repeated_runs_on_one_artifact_agree() {
    let model = vgg_tiny(10, 16);
    let params = fake_params(model.param_count, 13);
    let cfg = BitConfig::uniform(model.num_layers(), 4);
    let compiled = CompiledModel::compile(&model, &params, &cfg, Method::RpSlbc).unwrap();
    let img = probe_image(16, 21);
    let first = compiled.run(&img).unwrap();
    for _ in 0..3 {
        let again = compiled.run(&img).unwrap();
        assert_eq!(first.logits, again.logits);
        assert_eq!(first.pred, again.pred);
        assert_eq!(first.cycles, again.cycles);
        assert_eq!(first.per_layer, again.per_layer);
        assert_eq!(first.counter, again.counter);
    }
}

#[test]
fn mixed_bit_configs_also_equivalent() {
    // Non-uniform (NAS-style) configurations through the SLBC methods.
    let model = vgg_tiny(10, 16);
    let params = fake_params(model.param_count, 17);
    let img = probe_image(16, 3);
    let cfg = BitConfig {
        wbits: vec![8, 4, 3, 5, 2, 8],
        abits: vec![4, 4, 6, 3, 4, 8],
    };
    for method in [Method::Slbc, Method::RpSlbc] {
        let via_deploy = deploy(&model, &params, &cfg, method, &img).unwrap();
        let compiled = CompiledModel::compile(&model, &params, &cfg, method).unwrap();
        let via_run = compiled.report(&img).unwrap();
        assert_eq!(via_deploy.cycles, via_run.cycles, "{}", method.name());
        assert_eq!(via_deploy.per_layer, via_run.per_layer, "{}", method.name());
    }
}
