//! Acceptance tests for the native mixed-precision co-design search.
//!
//! Pins (ISSUE acceptance criteria):
//! 1. on both registry targets the best-cycles Pareto point strictly
//!    beats uniform int8 on predicted cycles at equal-or-smaller flash;
//! 2. every front point re-proves analyzer-clean (zero Error findings);
//! 3. the search is bit-deterministic per seed — two runs with the same
//!    seed produce identical fronts, objective-for-objective;
//! 4. saved configs round-trip through `save_config`/`load_config` and
//!    re-enter the serve layer as first-class workloads.

use mcu_mixq::analysis;
use mcu_mixq::engine::CompiledModel;
use mcu_mixq::models::{vgg_tiny, ModelDesc};
use mcu_mixq::nas::search::{native_search, NativeSearchCfg};
use mcu_mixq::quant::{load_config, save_config, BitConfig};
use mcu_mixq::target::Target;
use mcu_mixq::util::prng::Rng;

fn setup() -> (ModelDesc, Vec<f32>) {
    let model = vgg_tiny(10, 16);
    let mut rng = Rng::new(1000);
    let params = (0..model.param_count).map(|_| rng.normal() * 0.1).collect();
    (model, params)
}

#[test]
fn searched_beats_uniform8_and_front_is_analyzer_clean() {
    let (model, params) = setup();
    let cfg = NativeSearchCfg::smoke(7);
    for name in ["stm32f746", "stm32f446"] {
        let target = Target::resolve(name).unwrap();
        let out = native_search(&model, &params, target, &cfg).unwrap();
        assert!(!out.front.is_empty(), "{name}: empty Pareto front");

        // Acceptance: strictly fewer predicted cycles than uniform int8
        // at equal-or-smaller flash (model size).
        let best = out.best_cycles();
        assert!(
            best.obj.cycles < out.uniform8.cycles,
            "{name}: best-cycles {} must beat uniform8 {}",
            best.obj.cycles,
            out.uniform8.cycles
        );
        assert!(
            best.obj.flash_total_bytes <= out.uniform8.flash_total_bytes,
            "{name}: searched flash {} exceeds uniform8 {}",
            best.obj.flash_total_bytes,
            out.uniform8.flash_total_bytes
        );

        // Acceptance: every front point passes the static analyzer with
        // zero Error findings (independent recompile, not the memo).
        for p in &out.front {
            let cm = CompiledModel::compile_unbounded_for(
                &model, &params, &p.cfg, cfg.method, target,
            );
            let report = analysis::analyze(&cm);
            assert_eq!(
                report.errors(),
                0,
                "{name}: front point w={:?} a={:?} has Errors: {:?}",
                p.cfg.wbits,
                p.cfg.abits,
                report.error_rules()
            );
        }
    }
}

#[test]
fn search_is_bit_deterministic_per_seed() {
    let (model, params) = setup();
    let target = Target::resolve("stm32f446").unwrap();
    let cfg = NativeSearchCfg::smoke(42);
    let a = native_search(&model, &params, target, &cfg).unwrap();
    let b = native_search(&model, &params, target, &cfg).unwrap();
    assert_eq!(a.front.len(), b.front.len());
    for (pa, pb) in a.front.iter().zip(&b.front) {
        assert_eq!(pa.cfg, pb.cfg);
        assert_eq!(pa.obj.cycles, pb.obj.cycles);
        assert_eq!(pa.obj.sram_peak_bytes, pb.obj.sram_peak_bytes);
        assert_eq!(pa.obj.flash_total_bytes, pb.obj.flash_total_bytes);
        assert_eq!(pa.obj.joules.to_bits(), pb.obj.joules.to_bits());
        assert_eq!(
            pa.obj.accuracy_proxy_db.to_bits(),
            pb.obj.accuracy_proxy_db.to_bits()
        );
    }
    assert_eq!(a.evaluated, b.evaluated);
    assert_eq!(a.pruned, b.pruned);
}

#[test]
fn saved_config_round_trips_and_feeds_serve() {
    let cfg = BitConfig {
        wbits: vec![4, 2, 8, 4, 6, 8],
        abits: vec![8, 4, 4, 8, 6, 8],
    };
    let path = std::env::temp_dir().join("mixq_nas_search_roundtrip.json");
    let path = path.to_str().unwrap();
    save_config(path, "vgg_tiny", &cfg).unwrap();
    let (backbone, loaded) = load_config(path).unwrap();
    assert_eq!(backbone, "vgg_tiny");
    assert_eq!(loaded, cfg);

    // A searched config is a first-class serve workload (ModelKey hashes
    // the full per-layer bit vector).
    let w = mcu_mixq::serve::Workload::with_config(
        &backbone,
        mcu_mixq::ops::Method::RpSlbc,
        loaded.clone(),
        5,
    )
    .unwrap();
    assert_eq!(w.key.cfg, cfg);
    std::fs::remove_file(path).ok();
}

#[test]
fn load_config_rejects_garbage() {
    let dir = std::env::temp_dir();
    let bad = dir.join("mixq_nas_search_bad.json");
    std::fs::write(&bad, "{\"backbone\": \"x\", \"wbits\": [4], \"abits\": [4, 8]}").unwrap();
    assert!(load_config(bad.to_str().unwrap()).is_err());
    std::fs::write(&bad, "not json").unwrap();
    assert!(load_config(bad.to_str().unwrap()).is_err());
    std::fs::remove_file(&bad).ok();
}
