"""AOT compile path: lower every Layer-2 program to HLO *text* artifacts.

Run as ``python -m compile.aot --out-dir ../artifacts`` (wrapped by
``make artifacts``). Python runs exactly once; afterwards the Rust binary is
self-contained.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Emitted per backbone (vgg_tiny on synth-CIFAR, mobilenet_tiny on synth-VWW):

* ``<bb>_qat_step.hlo.txt``       — QAT SGD step, runtime bitwidth tensors
* ``<bb>_eval.hlo.txt``           — eval loss/accuracy on a big batch
* ``<bb>_infer.hlo.txt``          — batch-1 logits
* ``<bb>_supernet_step.hlo.txt``  — differentiable NAS step (cost table in)
* ``<bb>_init.bin``               — flat f32 LE initial parameters

Plus ``slbc_demo.hlo.txt`` (the Layer-1 packed-convolution kernel standalone,
int64 carrier) and ``manifest.json`` describing shapes, offsets and layer
geometry for the Rust side.
"""

from __future__ import annotations

import argparse
import json
import os
from dataclasses import asdict

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct as Spec
from jax._src.lib import xla_client as xc

from . import model as M

TRAIN_BATCH = 64
EVAL_BATCH = 256
INFER_BATCH = 1

#: slbc_demo geometry — mirrored in the manifest for the Rust consumer.
SLBC_DEMO = {"n": 64, "k": 5, "sx_bits": 4, "sk_bits": 4}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1024:.0f} KiB)")


def f32(*shape):
    return Spec(shape, jnp.float32)


def i32(*shape):
    return Spec(shape, jnp.int32)


def lower_backbone(bb: M.Backbone, out_dir: str) -> dict:
    """Lower all four programs of one backbone; return its manifest entry."""
    L, K = bb.num_layers, len(M.OPTIONS)
    P = bb.param_count
    hw, c = bb.input_hw, bb.input_c

    def x_spec(b):
        return f32(b, hw, hw, c)

    arts = {}

    qat = M.make_qat_train_step(bb)
    lowered = jax.jit(qat).lower(
        f32(P), f32(P), x_spec(TRAIN_BATCH), i32(TRAIN_BATCH), f32(L), f32(L), f32()
    )
    arts["qat_step"] = f"{bb.name}_qat_step.hlo.txt"
    _write(os.path.join(out_dir, arts["qat_step"]), to_hlo_text(lowered))

    ev = M.make_eval_step(bb)
    lowered = jax.jit(ev).lower(
        f32(P), x_spec(EVAL_BATCH), i32(EVAL_BATCH), f32(L), f32(L)
    )
    arts["eval"] = f"{bb.name}_eval.hlo.txt"
    _write(os.path.join(out_dir, arts["eval"]), to_hlo_text(lowered))

    inf = M.make_infer(bb)
    lowered = jax.jit(inf).lower(f32(P), x_spec(INFER_BATCH), f32(L), f32(L))
    arts["infer"] = f"{bb.name}_infer.hlo.txt"
    _write(os.path.join(out_dir, arts["infer"]), to_hlo_text(lowered))

    sn = M.make_supernet_train_step(bb)
    lowered = jax.jit(sn).lower(
        f32(P), f32(P), f32(L, K), f32(L, K),
        x_spec(TRAIN_BATCH), i32(TRAIN_BATCH),
        f32(L, K, K), f32(), f32(), f32(),
    )
    arts["supernet_step"] = f"{bb.name}_supernet_step.hlo.txt"
    _write(os.path.join(out_dir, arts["supernet_step"]), to_hlo_text(lowered))

    params = M.init_params(bb, seed=0)
    init_path = f"{bb.name}_init.bin"
    with open(os.path.join(out_dir, init_path), "wb") as f:
        f.write(bytes(memoryview(jax.device_get(params).astype("<f4"))))
    print(f"  wrote {out_dir}/{init_path} ({P} params)")

    return {
        "input_hw": hw,
        "input_c": c,
        "num_classes": bb.num_classes,
        "num_layers": L,
        "param_count": P,
        "train_batch": TRAIN_BATCH,
        "eval_batch": EVAL_BATCH,
        "infer_batch": INFER_BATCH,
        "layers": [asdict(l) for l in bb.layers],
        "artifacts": arts,
        "init": init_path,
    }


def lower_slbc_demo(out_dir: str) -> dict:
    """Lower the standalone Layer-1 SLBC kernel (int64 carrier)."""
    jax.config.update("jax_enable_x64", True)
    from .kernels import slbc

    n, k = SLBC_DEMO["n"], SLBC_DEMO["k"]
    sx, sk = SLBC_DEMO["sx_bits"], SLBC_DEMO["sk_bits"]

    def demo(x, kern):
        return slbc.slbc_conv1d_full(x, kern, sx_bits=sx, sk_bits=sk)

    lowered = jax.jit(demo).lower(
        Spec((n,), jnp.int64), Spec((k,), jnp.int64)
    )
    _write(os.path.join(out_dir, "slbc_demo.hlo.txt"), to_hlo_text(lowered))
    entry = dict(SLBC_DEMO)
    entry["artifact"] = "slbc_demo.hlo.txt"
    entry["group_size"] = slbc.group_size(sx, sk, k)
    entry["field_width"] = slbc.field_width(sx, sk, k)
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "version": 1,
        "options": M.OPTIONS,
        "momentum": M.MOMENTUM,
        "backbones": {},
    }
    for name, num_classes in [("vgg_tiny", 10), ("mobilenet_tiny", 2)]:
        print(f"lowering {name} ...")
        bb = M.BACKBONES[name](num_classes=num_classes)
        manifest["backbones"][name] = lower_backbone(bb, args.out_dir)

    print("lowering slbc_demo ...")
    manifest["slbc_demo"] = lower_slbc_demo(args.out_dir)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
