"""Build-time compile path for MCU-MixQ.

Everything in this package runs ONCE at ``make artifacts`` and never on the
request path. It authors the Layer-1 Pallas kernels and the Layer-2 JAX
model/supernet, and AOT-lowers them to HLO text consumed by the Rust
Layer-3 coordinator.
"""
