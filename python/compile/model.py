"""Layer-2: mixed-precision CNN + EdMIPS-style quantization supernet (JAX).

This module defines — entirely at build time — every compute graph the Rust
Layer-3 coordinator executes through PJRT:

* ``qat_train_step`` / ``eval_step`` / ``infer``: the mixed-precision model
  with per-layer weight/activation bitwidths as *runtime tensors*, so one
  artifact serves every quantization configuration the NAS emits.
* ``supernet_train_step``: the differentiable hardware-aware quantization
  explorer (paper §III.B). Each layer holds branch logits over the bitwidth
  options; the complexity loss contracts ``softmax(alpha_w) · C ·
  softmax(alpha_a)`` against a cost table **supplied by Rust as an input**
  — the HW/SW co-design seam: Layer 3's Eq. 12 packing performance model
  drives Layer 2's gradient-based search.

All quantizers are the Layer-1 Pallas kernels from ``kernels.quant``; the
model layer math is checked against ``kernels.ref`` by the pytest suite.

Parameters live in ONE flat f32 vector (offsets recorded in the manifest),
which keeps the Rust FFI surface to a handful of buffers per step.
"""

from __future__ import annotations

from dataclasses import dataclass, field, asdict
from typing import List

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import ref
from .kernels.quant import fake_quant_signed, fake_quant_unsigned

#: Bitwidth options of the quantization search space Q (paper §III.B).
#: MCU-MixQ supports every integer bitwidth in [2, 8].
OPTIONS: List[int] = [2, 3, 4, 5, 6, 7, 8]

#: SGD momentum used by both training loops.
MOMENTUM = 0.9


@dataclass
class LayerSpec:
    """One quantizable layer. Mirrored verbatim into the JSON manifest so
    the Rust side (perf model, engine, planner) sees identical geometry."""

    name: str
    kind: str  # "conv" | "dwconv" | "dense"
    cin: int
    cout: int
    k: int = 1
    stride: int = 1
    in_h: int = 1
    in_w: int = 1
    out_h: int = 1
    out_w: int = 1
    pool_after: bool = False  # 2x2 max-pool after activation
    gap_before: bool = False  # global-average-pool before a dense layer
    w_offset: int = 0
    w_size: int = 0
    b_offset: int = 0
    b_size: int = 0
    macs: int = 0

    def weight_shape(self):
        if self.kind == "conv":
            return (self.k, self.k, self.cin, self.cout)
        if self.kind == "dwconv":
            return (self.k, self.k, 1, self.cout)
        if self.kind == "dense":
            return (self.cin, self.cout)
        raise ValueError(self.kind)


@dataclass
class Backbone:
    """A model family entry of the zoo (VGG-Tiny / MobileNet-Tiny)."""

    name: str
    input_hw: int
    input_c: int
    num_classes: int
    layers: List[LayerSpec] = field(default_factory=list)
    param_count: int = 0

    @property
    def num_layers(self) -> int:
        return len(self.layers)


def _finalize(bb: Backbone) -> Backbone:
    """Assign flat-vector offsets and MAC counts."""
    off = 0
    for l in bb.layers:
        wshape = l.weight_shape()
        l.w_offset = off
        l.w_size = int(jnp.prod(jnp.array(wshape)))
        off += l.w_size
        l.b_offset = off
        l.b_size = l.cout
        off += l.b_size
        if l.kind == "conv":
            l.macs = l.out_h * l.out_w * l.k * l.k * l.cin * l.cout
        elif l.kind == "dwconv":
            l.macs = l.out_h * l.out_w * l.k * l.k * l.cout
        else:
            l.macs = l.cin * l.cout
    bb.param_count = off
    return bb


def vgg_tiny(num_classes: int = 10, hw: int = 16) -> Backbone:
    """VGG-Tiny: the paper's VGG-style compact backbone (Table I row 1).

    conv16-conv16-pool / conv32-conv32-pool / conv64-pool / dense."""
    h = hw
    layers = [
        LayerSpec("conv1", "conv", 3, 16, 3, 1, h, h, h, h),
        LayerSpec("conv2", "conv", 16, 16, 3, 1, h, h, h, h, pool_after=True),
    ]
    h //= 2
    layers += [
        LayerSpec("conv3", "conv", 16, 32, 3, 1, h, h, h, h),
        LayerSpec("conv4", "conv", 32, 32, 3, 1, h, h, h, h, pool_after=True),
    ]
    h //= 2
    layers += [
        LayerSpec("conv5", "conv", 32, 64, 3, 1, h, h, h, h, pool_after=True),
    ]
    h //= 2
    layers += [
        LayerSpec("fc", "dense", h * h * 64, num_classes),
    ]
    return _finalize(Backbone("vgg_tiny", hw, 3, num_classes, layers))


def mobilenet_tiny(num_classes: int = 2, hw: int = 16) -> Backbone:
    """MobileNet-Tiny: depthwise-separable compact backbone (Table I row 2).

    conv16 / dw+pw32-pool / dw+pw64-pool / dw+pw64 / GAP-dense."""
    h = hw
    layers = [
        LayerSpec("conv1", "conv", 3, 16, 3, 1, h, h, h, h),
        LayerSpec("dw1", "dwconv", 16, 16, 3, 1, h, h, h, h),
        LayerSpec("pw1", "conv", 16, 32, 1, 1, h, h, h, h, pool_after=True),
    ]
    h //= 2
    layers += [
        LayerSpec("dw2", "dwconv", 32, 32, 3, 1, h, h, h, h),
        LayerSpec("pw2", "conv", 32, 64, 1, 1, h, h, h, h, pool_after=True),
    ]
    h //= 2
    layers += [
        LayerSpec("dw3", "dwconv", 64, 64, 3, 1, h, h, h, h),
        LayerSpec("pw3", "conv", 64, 64, 1, 1, h, h, h, h),
        LayerSpec("fc", "dense", 64, num_classes, gap_before=True),
    ]
    return _finalize(Backbone("mobilenet_tiny", hw, 3, num_classes, layers))


BACKBONES = {
    "vgg_tiny": vgg_tiny,
    "mobilenet_tiny": mobilenet_tiny,
}


# --------------------------------------------------------------------------
# Parameter handling
# --------------------------------------------------------------------------


def init_params(bb: Backbone, seed: int = 0) -> jnp.ndarray:
    """He-initialised flat parameter vector (deterministic)."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for l in bb.layers:
        key, sub = jax.random.split(key)
        wshape = l.weight_shape()
        fan_in = l.k * l.k * (1 if l.kind == "dwconv" else l.cin)
        if l.kind == "dense":
            fan_in = l.cin
        std = (2.0 / max(fan_in, 1)) ** 0.5
        chunks.append(jax.random.normal(sub, wshape, jnp.float32).reshape(-1) * std)
        chunks.append(jnp.zeros((l.cout,), jnp.float32))
    return jnp.concatenate(chunks)


def _slice_params(flat: jnp.ndarray, l: LayerSpec):
    w = flat[l.w_offset : l.w_offset + l.w_size].reshape(l.weight_shape())
    b = flat[l.b_offset : l.b_offset + l.b_size]
    return w, b


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------


def _apply_layer(l: LayerSpec, h: jnp.ndarray, wq: jnp.ndarray, b: jnp.ndarray,
                 last: bool) -> jnp.ndarray:
    if l.kind == "conv":
        h = ref.conv2d_nhwc(h, wq, l.stride) + b
    elif l.kind == "dwconv":
        h = ref.depthwise_conv2d_nhwc(h, wq, l.stride) + b
    else:
        if l.gap_before:
            h = ref.global_avg_pool(h)
        elif h.ndim == 4:
            h = h.reshape(h.shape[0], -1)
        h = ref.dense(h, wq, b)
    if not last:
        h = jax.nn.relu(h)
        if l.pool_after:
            h = ref.max_pool_2x2(h)
    return h


def forward(bb: Backbone, flat: jnp.ndarray, x: jnp.ndarray,
            wbits: jnp.ndarray, abits: jnp.ndarray) -> jnp.ndarray:
    """Mixed-precision forward with per-layer runtime bitwidths.

    ``wbits``/``abits`` are f32 vectors of length ``bb.num_layers`` — the
    exact tensors the Rust coordinator ships after the NAS picks a config.
    """
    h = x
    n = bb.num_layers
    for i, l in enumerate(bb.layers):
        w, b = _slice_params(flat, l)
        wq = fake_quant_signed(w, wbits[i])
        h = fake_quant_unsigned(h, abits[i]) if i > 0 else h
        h = _apply_layer(l, h, wq, b, last=(i == n - 1))
    return h


def _hard_mix(logits_row: jnp.ndarray) -> jnp.ndarray:
    """Straight-through branch weights: forward uses the argmax branch
    (one-hot), gradients flow through the softmax.

    A pure soft mixture lets the supernet co-adapt to the *average* of all
    quantization branches, so the cross-entropy stops penalizing cheap
    branches and the complexity loss drags every layer to the minimum
    bitwidth (the classic DNAS collapse). Hard selection keeps the CE tied
    to the configuration that argmax will actually select.
    """
    sm = jax.nn.softmax(logits_row)
    hard = jax.nn.one_hot(jnp.argmax(sm), sm.shape[-1], dtype=sm.dtype)
    return hard + sm - lax.stop_gradient(sm)


def supernet_forward(bb: Backbone, flat: jnp.ndarray,
                     alpha_w: jnp.ndarray, alpha_a: jnp.ndarray,
                     x: jnp.ndarray) -> jnp.ndarray:
    """EdMIPS-style composite forward over quantization branches.

    Weights use the softmax-weighted mix of branches (the efficient
    factorised form — mix quantized weights, then one convolution);
    activations use straight-through hard selection (see [`_hard_mix`]),
    which anchors the search to configurations whose *discrete* selection
    is actually trainable.
    """
    h = x
    n = bb.num_layers
    sm_w = jax.nn.softmax(alpha_w, axis=1)  # [L, K]
    for i, l in enumerate(bb.layers):
        w, b = _slice_params(flat, l)
        wq = sum(
            sm_w[i, j] * fake_quant_signed(w, float(opt))
            for j, opt in enumerate(OPTIONS)
        )
        if i > 0:
            mix_a = _hard_mix(alpha_a[i])
            h = sum(
                mix_a[j] * fake_quant_unsigned(h, float(opt))
                for j, opt in enumerate(OPTIONS)
            )
        h = _apply_layer(l, h, wq, b, last=(i == n - 1))
    return h


# --------------------------------------------------------------------------
# Losses and train/eval steps
# --------------------------------------------------------------------------


def _ce_and_acc(logits: jnp.ndarray, y: jnp.ndarray):
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(y, logits.shape[-1], dtype=jnp.float32)
    ce = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return ce, acc


def make_qat_train_step(bb: Backbone):
    """(params, mom, x, y, wbits, abits, lr) -> (params', mom', loss, acc).

    Plain SGD+momentum QAT step (paper's final stage before deployment)."""

    def step(flat, mom, x, y, wbits, abits, lr):
        def loss_fn(p):
            logits = forward(bb, p, x, wbits, abits)
            return _ce_and_acc(logits, y)

        (loss, acc), g = jax.value_and_grad(loss_fn, has_aux=True)(flat)
        mom2 = MOMENTUM * mom + g
        flat2 = flat - lr * mom2
        return flat2, mom2, loss, acc

    return step


def make_eval_step(bb: Backbone):
    """(params, x, y, wbits, abits) -> (loss, acc)."""

    def step(flat, x, y, wbits, abits):
        logits = forward(bb, flat, x, wbits, abits)
        loss, acc = _ce_and_acc(logits, y)
        return loss, acc

    return step


def make_infer(bb: Backbone):
    """(params, x, wbits, abits) -> logits — batch-1 deployment graph."""

    def run(flat, x, wbits, abits):
        return forward(bb, flat, x, wbits, abits)

    return run


def make_supernet_train_step(bb: Backbone):
    """The hardware-aware quantization explorer's inner step.

    Signature (all f32 unless noted):
        (params, mom, alpha_w[L,K], alpha_a[L,K], x, y(int32),
         cost[L,K,K], lr, lr_alpha, lam)
        -> (params', mom', alpha_w', alpha_a',
            loss, acc_loss, comp_loss, acc)

    ``cost[l, i, j]`` is the Layer-3 packing performance model's predicted
    complexity (Eq. 12) of layer ``l`` at weight-bitwidth ``OPTIONS[i]`` and
    activation-bitwidth ``OPTIONS[j]``, normalised by Rust. The complexity
    loss is its bilinear expectation under the branch softmaxes (Eq. 1–2),
    so its gradient steers the alphas toward bitwidths that are *cheap under
    SLBC packing*, not merely low.
    """

    def step(flat, mom, alpha_w, alpha_a, x, y, cost, lr, lr_alpha, lam):
        def loss_fn(p, aw, aa):
            logits = supernet_forward(bb, p, aw, aa, x)
            ce, acc = _ce_and_acc(logits, y)
            sm_w = jax.nn.softmax(aw, axis=1)
            sm_a = jax.nn.softmax(aa, axis=1)
            comp = jnp.sum(jnp.einsum("li,lij,lj->l", sm_w, cost, sm_a))
            total = ce + lam * comp
            return total, (ce, lam * comp, acc)

        grads = jax.grad(loss_fn, argnums=(0, 1, 2), has_aux=True)
        (gp, gw, ga), (ce, comp, acc) = grads(flat, alpha_w, alpha_a)
        mom2 = MOMENTUM * mom + gp
        flat2 = flat - lr * mom2
        aw2 = alpha_w - lr_alpha * gw
        aa2 = alpha_a - lr_alpha * ga
        total = ce + comp
        return flat2, mom2, aw2, aa2, total, ce, comp, acc

    return step
