"""Pure-``jnp`` reference oracles for the Layer-1 Pallas kernels.

These are deliberately written in the most direct, obviously-correct style
(no packing tricks, no bit manipulation) so the pytest/hypothesis suites can
use them as ground truth for:

* :func:`conv1d_full`      — the polynomial/convolution identity (paper
  Eq. 5–7) the SLBC kernel exploits,
* :func:`fake_quant_signed` / :func:`fake_quant_unsigned` — the uniform
  quantizers the QAT path and the NAS supernet branches apply,
* :func:`conv2d_nhwc` / :func:`depthwise_conv2d_nhwc` / :func:`dense`
  — the layer math of the Layer-2 model.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def conv1d_full(s, k):
    """Full 1-D convolution ``y[n] = sum_m s[n-m] * k[m]`` (paper Eq. 6).

    ``s`` has ``N`` elements and ``k`` has ``K``; the result has
    ``N + K - 1`` elements. This is true convolution (kernel flipped), the
    orientation under which packed polynomial multiplication (Eq. 5) equals
    the convolution sequence.
    """
    return jnp.convolve(s, k, mode="full")


def fake_quant_signed(x, bits):
    """Symmetric signed uniform fake-quantization with dynamic max-abs scale.

    ``n = 2**(bits-1) - 1`` levels per sign; the scale is derived from the
    tensor's max-abs so no quantization state needs to cross the AOT
    boundary. ``bits`` may be a traced float tensor (the Rust coordinator
    feeds per-layer bitwidths at run time).
    """
    n = jnp.exp2(bits - 1.0) - 1.0
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    scale = amax / n
    return jnp.clip(jnp.round(x / scale), -n, n) * scale


def fake_quant_unsigned(x, bits):
    """Unsigned uniform fake-quantization (for post-ReLU activations).

    ``n = 2**bits - 1`` levels; inputs are clipped at zero first.
    """
    n = jnp.exp2(bits) - 1.0
    xp = jnp.maximum(x, 0.0)
    amax = jnp.maximum(jnp.max(xp), 1e-8)
    scale = amax / n
    return jnp.clip(jnp.round(xp / scale), 0.0, n) * scale


def conv2d_nhwc(x, w, stride=1, padding="SAME"):
    """Standard 2-D convolution, NHWC activations, HWIO weights."""
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def depthwise_conv2d_nhwc(x, w, stride=1, padding="SAME"):
    """Depthwise 2-D convolution; ``w`` is HWIO with I == channel count and
    O == 1, reshaped to the grouped form lax expects."""
    c = x.shape[-1]
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )


def dense(x, w, b):
    """Fully-connected layer: ``x @ w + b``."""
    return x @ w + b


def max_pool_2x2(x):
    """2x2 max pooling, stride 2, NHWC."""
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )


def global_avg_pool(x):
    """Global average pooling over H and W, NHWC -> NC."""
    return jnp.mean(x, axis=(1, 2))
