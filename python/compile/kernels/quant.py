"""Fake-quantization Pallas kernels with straight-through gradients.

These are the Layer-1 kernels the Layer-2 model calls on *every* quantized
tensor — weights and activations of every layer, and all supernet branches —
so they lower into every HLO artifact the Rust coordinator executes.

Design notes
------------
* Scales are dynamic (max-abs per tensor), so no quantization state crosses
  the AOT boundary; the Rust side only ever ships bitwidths.
* Bitwidths are *traced* float tensors. One ``qat_train_step`` artifact
  therefore serves every quantization configuration the NAS emits — the
  coordinator feeds ``wbits[L]`` / ``abits[L]`` as inputs at run time.
* Gradients use the straight-through estimator (identity through ``round``,
  clipped outside the representable range), via ``jax.custom_vjp`` — Pallas
  kernels have no autodiff rule, and STE is what the paper's QAT stage uses.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fq_signed_kernel(x_ref, bits_ref, o_ref):
    """Symmetric signed uniform quantizer: n = 2^(b-1) - 1 levels/sign."""
    x = x_ref[...]
    n = jnp.exp2(bits_ref[0] - 1.0) - 1.0
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    scale = amax / n
    o_ref[...] = jnp.clip(jnp.round(x / scale), -n, n) * scale


def _fq_unsigned_kernel(x_ref, bits_ref, o_ref):
    """Unsigned uniform quantizer for post-ReLU activations: n = 2^b - 1."""
    x = jnp.maximum(x_ref[...], 0.0)
    n = jnp.exp2(bits_ref[0]) - 1.0
    amax = jnp.maximum(jnp.max(x), 1e-8)
    scale = amax / n
    o_ref[...] = jnp.clip(jnp.round(x / scale), 0.0, n) * scale


def _call_fq(kernel, x, bits):
    flat = x.reshape(-1)
    bits_arr = jnp.asarray(bits, jnp.float32).reshape(1)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(flat.shape, flat.dtype),
        interpret=True,
    )(flat, bits_arr)
    return out.reshape(x.shape)


@jax.custom_vjp
def fake_quant_signed(x, bits):
    """STE-wrapped signed fake-quant (weights)."""
    return _call_fq(_fq_signed_kernel, x, bits)


def _fqs_fwd(x, bits):
    return _call_fq(_fq_signed_kernel, x, bits), None


def _fqs_bwd(_, g):
    # Straight-through: identity to x, no gradient to the bitwidth.
    return g, None


fake_quant_signed.defvjp(_fqs_fwd, _fqs_bwd)


@jax.custom_vjp
def fake_quant_unsigned(x, bits):
    """STE-wrapped unsigned fake-quant (post-ReLU activations).

    The backward pass gates the gradient at zero (the ReLU clip is part of
    the quantizer), matching the conventional QAT treatment.
    """
    return _call_fq(_fq_unsigned_kernel, x, bits)


def _fqu_fwd(x, bits):
    return _call_fq(_fq_unsigned_kernel, x, bits), (x > 0.0)


def _fqu_bwd(res, g):
    return jnp.where(res, g, 0.0), None


fake_quant_unsigned.defvjp(_fqu_fwd, _fqu_bwd)
