"""Layer-1 Pallas kernels for MCU-MixQ.

* :mod:`slbc`  — the paper's SIMD Low-Bitwidth Convolution expressed as
  packed integer arithmetic in a Pallas kernel (interpret mode).
* :mod:`quant` — fake-quantization kernels (signed / unsigned uniform)
  with straight-through-estimator gradients; these are the kernels the
  Layer-2 model and supernet call on every quantized tensor.
* :mod:`ref`   — pure-``jnp`` oracles both kernels are tested against.
"""
