"""SLBC — SIMD Low-Bitwidth Convolution as a Pallas kernel (Layer 1).

The paper's core arithmetic trick (Eq. 3–7): pack several ``s_b``-bit
operands into one wide integer so that a *single* multiplication computes
many multiply-accumulates at once, then segment the convolution outputs out
of the product's bit-fields:

    R1 = sum_i s[i] * 2^(i*S)          (packed signal group)
    R2 = sum_j k[j] * 2^(j*S)          (packed kernel)
    P  = R1 * R2 = sum_n y[n] * 2^(n*S)   with  y = conv_full(s, k)

On the Cortex-M7 the "wide integer" is a 32-bit DSP register treated as
SIMD lanes; the Rust Layer-3 operators replay exactly this scheme on the
cycle-level MCU simulator. Here the same insight is re-expressed for the
TPU-era stack (see DESIGN.md §Hardware-Adaptation): a Pallas kernel packs
groups into int64 "registers", performs one multiply per group, and extracts
the fields — raising effective MACs per hardware multiply exactly as the
paper raises MACs per SIMD instruction. ``interpret=True`` throughout (the
CPU PJRT plugin cannot execute Mosaic custom-calls).

Field-width rule (guard bits): with ``sx``-bit signal, ``sk``-bit kernel and
``K`` taps, a convolution output needs ``sx + sk + ceil(log2(K))`` bits, so
the field stride ``S`` must satisfy that bound, and a 63-bit register packs
``G = floor(63 / S) - K + 1`` signal elements per multiply (the top ``K-1``
fields of the product spill into the next group — the overlap the paper's
segmentation stage, and RP-SLBC's reordering, deal with).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

#: Width in bits of the simulated wide register. int64 is used as the
#: carrier; one sign bit is reserved, hence 63 usable bits.
REGISTER_BITS = 63


def field_width(sx_bits: int, sk_bits: int, k_taps: int) -> int:
    """Minimal field stride ``S`` so packed convolution outputs never carry
    into the neighbouring field (paper §IV.A, the guard-bit condition)."""
    if k_taps < 1:
        raise ValueError("kernel must have at least one tap")
    guard = max(1, math.ceil(math.log2(k_taps))) if k_taps > 1 else 0
    return sx_bits + sk_bits + guard


def group_size(sx_bits: int, sk_bits: int, k_taps: int) -> int:
    """Number of signal elements packed per wide multiply.

    The product of a ``G``-field signal register and a ``K``-field kernel
    register occupies ``G + K - 1`` fields, all of which must fit in the
    63-bit carrier.
    """
    s = field_width(sx_bits, sk_bits, k_taps)
    g = REGISTER_BITS // s - (k_taps - 1)
    if g < 1:
        raise ValueError(
            f"bitwidths sx={sx_bits} sk={sk_bits} with K={k_taps} taps do "
            f"not fit a {REGISTER_BITS}-bit register"
        )
    return g


def _slbc_kernel(x_ref, k_ref, o_ref, *, sx_bits, sk_bits, k_taps, n_groups, g):
    """Pallas kernel body: pack → multiply → segment, one group per step.

    The output ref is pre-zeroed and accumulated across groups with the
    overlap handling of Eq. 11: fields ``>= G`` of group ``i`` land in the
    territory of group ``i+1``.
    """
    s = field_width(sx_bits, sk_bits, k_taps)
    mask = jnp.int64((1 << s) - 1)

    # Pack the kernel once: R2 = sum_j k[j] << (j*S)   (paper Eq. 4)
    shifts_k = (jnp.arange(k_taps, dtype=jnp.int64) * s).astype(jnp.int64)
    r2 = jnp.sum(k_ref[...].astype(jnp.int64) << shifts_k)

    o_ref[...] = jnp.zeros_like(o_ref)

    shifts_g = (jnp.arange(g, dtype=jnp.int64) * s).astype(jnp.int64)
    n_fields = g + k_taps - 1

    def body(i, _):
        # Pack one signal group: R1 = sum_i s[gi + i] << (i*S)  (Eq. 3)
        grp = lax.dynamic_slice(x_ref[...], (i * g,), (g,)).astype(jnp.int64)
        r1 = jnp.sum(grp << shifts_g)
        # One wide multiply performs g*k_taps MACs (Eq. 5).
        p = r1 * r2
        # Segmentation: extract the n_fields convolution outputs (Eq. 7)
        # and accumulate them at their global positions (Eq. 11).
        fields = (p >> (jnp.arange(n_fields, dtype=jnp.int64) * s)) & mask
        cur = lax.dynamic_slice(o_ref[...], (i * g,), (n_fields,))
        o_ref[...] = lax.dynamic_update_slice(o_ref[...], cur + fields, (i * g,))
        return 0

    lax.fori_loop(0, n_groups, body, 0)


def slbc_conv1d_full(x, k, *, sx_bits: int, sk_bits: int):
    """Full 1-D convolution of unsigned low-bitwidth sequences via packing.

    ``x``: int32/int64 array of non-negative ``sx_bits``-bit values
    (length padded internally to a multiple of the group size);
    ``k``: non-negative ``sk_bits``-bit kernel taps. Returns
    ``len(x) + len(k) - 1`` int64 outputs, bit-exact with
    :func:`ref.conv1d_full`.

    Signedness: like the MCU operators (and CMix-NN), signed operands are
    handled one level up by offsetting into unsigned range; the packed
    arithmetic itself is unsigned.
    """
    if not jax.config.jax_enable_x64:
        raise RuntimeError(
            "slbc kernels need jax_enable_x64 (the 63-bit carrier register)"
        )
    n = x.shape[0]
    k_taps = k.shape[0]
    g = group_size(sx_bits, sk_bits, k_taps)
    n_groups = -(-n // g)  # ceil
    n_pad = n_groups * g

    x64 = jnp.zeros((n_pad,), jnp.int64).at[:n].set(x.astype(jnp.int64))
    k64 = k.astype(jnp.int64)
    out_len = n_pad + k_taps - 1

    out = pl.pallas_call(
        partial(
            _slbc_kernel,
            sx_bits=sx_bits,
            sk_bits=sk_bits,
            k_taps=k_taps,
            n_groups=n_groups,
            g=g,
        ),
        out_shape=jax.ShapeDtypeStruct((out_len,), jnp.int64),
        interpret=True,
    )(x64, k64)
    return out[: n + k_taps - 1]


def _slbc_dot_kernel(a_ref, b_ref, o_ref, *, sa_bits, sb_bits, n, g):
    """Packed dot product: the dense-layer / im2col-inner-loop variant.

    Packs ``a`` ascending and ``b`` descending within each group so the
    middle field of the product accumulates the group's dot product — the
    same trick SLBC's Rust `conv_slbc` uses for the matmul-shaped inner
    loops, and the degenerate (single-output) case of Eq. 5.
    """
    s = field_width(sa_bits, sb_bits, g)
    mask = jnp.int64((1 << s) - 1)
    n_groups = n // g
    shifts_a = (jnp.arange(g, dtype=jnp.int64) * s).astype(jnp.int64)
    shifts_b = shifts_a[::-1]
    mid = jnp.int64((g - 1) * s)

    def body(i, acc):
        ga = lax.dynamic_slice(a_ref[...], (i * g,), (g,)).astype(jnp.int64)
        gb = lax.dynamic_slice(b_ref[...], (i * g,), (g,)).astype(jnp.int64)
        ra = jnp.sum(ga << shifts_a)
        rb = jnp.sum(gb << shifts_b)
        return acc + (((ra * rb) >> mid) & mask)

    o_ref[0] = lax.fori_loop(0, n_groups, body, jnp.int64(0))


def slbc_dot(a, b, *, sa_bits: int, sb_bits: int):
    """Packed dot product of two unsigned low-bitwidth vectors.

    Length is padded to a multiple of the group size; returns a scalar
    int64 equal to ``sum(a * b)``.
    """
    n = a.shape[0]
    # For a dot product every field accumulates up to g products, so the
    # guard must cover g itself; solve for the largest feasible g.
    g = 1
    while True:
        s_next = field_width(sa_bits, sb_bits, g + 1)
        if (2 * (g + 1) - 1) * s_next > REGISTER_BITS:
            break
        g += 1
    n_pad = -(-n // g) * g
    a64 = jnp.zeros((n_pad,), jnp.int64).at[:n].set(a.astype(jnp.int64))
    b64 = jnp.zeros((n_pad,), jnp.int64).at[:n].set(b.astype(jnp.int64))

    out = pl.pallas_call(
        partial(_slbc_dot_kernel, sa_bits=sa_bits, sb_bits=sb_bits, n=n_pad, g=g),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.int64),
        interpret=True,
    )(a64, b64)
    return out[0]


def macs_per_multiply(sx_bits: int, sk_bits: int, k_taps: int) -> int:
    """Effective MACs performed by one wide multiply — the quantity Fig. 6
    compares against CMix-NN's lanes-only packing."""
    return group_size(sx_bits, sk_bits, k_taps) * k_taps
