"""Layer-1 correctness: SLBC Pallas kernel vs the pure-jnp oracle.

The packed-arithmetic convolution must be *bit-exact* with direct
convolution for every in-range input — this is the core correctness signal
of the whole stack (the Rust MCU operators replay the identical scheme).
Hypothesis sweeps shapes and bitwidths.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, slbc


def _rand_unsigned(rng, n, bits):
    return rng.integers(0, 2**bits, size=n).astype(np.int64)


class TestFieldMath:
    def test_field_width_guard(self):
        # 4b x 4b with 5 taps needs 4+4+ceil(log2 5)=11 bits per field.
        assert slbc.field_width(4, 4, 5) == 11

    def test_field_width_single_tap(self):
        assert slbc.field_width(3, 2, 1) == 5

    def test_group_size_fits_register(self):
        for sx in range(1, 9):
            for sk in range(1, 9):
                for k in (1, 2, 3, 5, 9):
                    try:
                        g = slbc.group_size(sx, sk, k)
                    except ValueError:
                        continue
                    s = slbc.field_width(sx, sk, k)
                    assert (g + k - 1) * s <= slbc.REGISTER_BITS

    def test_macs_per_multiply_monotone_in_bits(self):
        # Lower bitwidths must pack at least as many MACs per multiply.
        m2 = slbc.macs_per_multiply(2, 2, 3)
        m8 = slbc.macs_per_multiply(8, 8, 3)
        assert m2 >= m8

    def test_group_size_rejects_oversize(self):
        with pytest.raises(ValueError):
            slbc.group_size(8, 8, 20)


class TestSlbcConv1d:
    @pytest.mark.parametrize("sx,sk,n,k", [
        (2, 2, 32, 3),
        (4, 4, 64, 5),
        (3, 5, 48, 3),
        (8, 8, 16, 2),
        (2, 8, 40, 4),
        (4, 2, 33, 7),  # n not a multiple of the group size
    ])
    def test_matches_reference(self, sx, sk, n, k):
        rng = np.random.default_rng(42 + sx * 100 + sk * 10 + k)
        x = _rand_unsigned(rng, n, sx)
        kern = _rand_unsigned(rng, k, sk)
        got = np.asarray(slbc.slbc_conv1d_full(
            jnp.asarray(x), jnp.asarray(kern), sx_bits=sx, sk_bits=sk))
        want = np.convolve(x, kern, mode="full")
        np.testing.assert_array_equal(got, want)

    def test_all_max_values_no_overflow(self):
        # Worst case: every operand at its bitwidth maximum.
        sx, sk, n, k = 4, 4, 64, 5
        x = np.full(n, 2**sx - 1, np.int64)
        kern = np.full(k, 2**sk - 1, np.int64)
        got = np.asarray(slbc.slbc_conv1d_full(
            jnp.asarray(x), jnp.asarray(kern), sx_bits=sx, sk_bits=sk))
        np.testing.assert_array_equal(got, np.convolve(x, kern, mode="full"))

    def test_zeros(self):
        got = np.asarray(slbc.slbc_conv1d_full(
            jnp.zeros(16, jnp.int64), jnp.zeros(3, jnp.int64),
            sx_bits=2, sk_bits=2))
        assert got.shape == (18,)
        assert not got.any()

    def test_impulse_recovers_kernel(self):
        kern = jnp.asarray([1, 3, 2], jnp.int64)
        x = jnp.zeros(10, jnp.int64).at[0].set(1)
        got = np.asarray(slbc.slbc_conv1d_full(x, kern, sx_bits=2, sk_bits=2))
        np.testing.assert_array_equal(got[:3], [1, 3, 2])

    @settings(max_examples=40, deadline=None)
    @given(
        sx=st.integers(2, 8),
        sk=st.integers(2, 8),
        n=st.integers(4, 80),
        k=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_bit_exact(self, sx, sk, n, k, seed):
        try:
            slbc.group_size(sx, sk, k)
        except ValueError:
            return  # config genuinely does not fit the register
        rng = np.random.default_rng(seed)
        x = _rand_unsigned(rng, n, sx)
        kern = _rand_unsigned(rng, k, sk)
        got = np.asarray(slbc.slbc_conv1d_full(
            jnp.asarray(x), jnp.asarray(kern), sx_bits=sx, sk_bits=sk))
        np.testing.assert_array_equal(got, np.convolve(x, kern, mode="full"))


class TestSlbcDot:
    @pytest.mark.parametrize("sa,sb,n", [(2, 2, 17), (4, 4, 64), (3, 6, 31)])
    def test_matches_reference(self, sa, sb, n):
        rng = np.random.default_rng(7 + n)
        a = _rand_unsigned(rng, n, sa)
        b = _rand_unsigned(rng, n, sb)
        got = int(slbc.slbc_dot(jnp.asarray(a), jnp.asarray(b),
                                sa_bits=sa, sb_bits=sb))
        assert got == int(np.dot(a, b))

    @settings(max_examples=30, deadline=None)
    @given(sa=st.integers(2, 8), sb=st.integers(2, 8),
           n=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
    def test_property(self, sa, sb, n, seed):
        rng = np.random.default_rng(seed)
        a = _rand_unsigned(rng, n, sa)
        b = _rand_unsigned(rng, n, sb)
        got = int(slbc.slbc_dot(jnp.asarray(a), jnp.asarray(b),
                                sa_bits=sa, sb_bits=sb))
        assert got == int(np.dot(a, b))


class TestRefOracleSanity:
    def test_conv1d_full_matches_polynomial_identity(self):
        # Eq. 5/7: packed product fields ARE the convolution sequence.
        rng = np.random.default_rng(0)
        s_bits, k_bits, k_taps = 3, 3, 3
        S = slbc.field_width(s_bits, k_bits, k_taps)
        x = _rand_unsigned(rng, 4, s_bits)
        kern = _rand_unsigned(rng, k_taps, k_bits)
        r1 = sum(int(v) << (i * S) for i, v in enumerate(x))
        r2 = sum(int(v) << (j * S) for j, v in enumerate(kern))
        p = r1 * r2
        fields = [(p >> (i * S)) & ((1 << S) - 1) for i in range(len(x) + k_taps - 1)]
        np.testing.assert_array_equal(fields, np.convolve(x, kern, mode="full"))
