"""Layer-1 correctness: fake-quant Pallas kernels vs the jnp oracle,
plus STE gradient behaviour (the property QAT relies on)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.quant import fake_quant_signed, fake_quant_unsigned


def _rand(rng, shape, lo=-3.0, hi=3.0):
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


class TestSignedKernel:
    @pytest.mark.parametrize("bits", [2.0, 3.0, 4.0, 6.0, 8.0])
    @pytest.mark.parametrize("shape", [(7,), (4, 5), (2, 3, 3, 4)])
    def test_matches_reference(self, bits, shape):
        rng = np.random.default_rng(int(bits) * 10 + len(shape))
        x = _rand(rng, shape)
        got = fake_quant_signed(x, bits)
        want = ref.fake_quant_signed(x, jnp.float32(bits))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    def test_level_count(self):
        # A b-bit signed quantizer emits at most 2^b - 1 distinct values.
        rng = np.random.default_rng(0)
        x = _rand(rng, (4096,))
        for b in (2, 3, 4):
            q = np.asarray(fake_quant_signed(x, float(b)))
            assert len(np.unique(q)) <= 2**b - 1

    def test_idempotent(self):
        rng = np.random.default_rng(1)
        x = _rand(rng, (128,))
        q1 = fake_quant_signed(x, 4.0)
        q2 = fake_quant_signed(q1, 4.0)
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-6)

    def test_ste_gradient_is_identity(self):
        rng = np.random.default_rng(2)
        x = _rand(rng, (32,))
        g = jax.grad(lambda v: jnp.sum(fake_quant_signed(v, 4.0)))(x)
        np.testing.assert_allclose(np.asarray(g), np.ones(32), atol=1e-6)

    @settings(max_examples=30, deadline=None)
    @given(bits=st.integers(2, 8), n=st.integers(1, 300),
           seed=st.integers(0, 2**31 - 1))
    def test_property_error_bounded_by_half_step(self, bits, n, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (n,))
        q = np.asarray(fake_quant_signed(x, float(bits)))
        levels = 2.0 ** (bits - 1) - 1.0
        scale = max(float(jnp.max(jnp.abs(x))), 1e-8) / levels
        assert np.max(np.abs(q - np.asarray(x))) <= scale / 2 + 1e-6


class TestUnsignedKernel:
    @pytest.mark.parametrize("bits", [2.0, 4.0, 8.0])
    def test_matches_reference(self, bits):
        rng = np.random.default_rng(int(bits))
        x = _rand(rng, (6, 6))
        got = fake_quant_unsigned(x, bits)
        want = ref.fake_quant_unsigned(x, jnp.float32(bits))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    def test_clips_negatives_to_zero(self):
        x = jnp.asarray([-1.0, -0.5, 0.5, 1.0], jnp.float32)
        q = np.asarray(fake_quant_unsigned(x, 4.0))
        assert (q[:2] == 0).all() and (q[2:] > 0).all()

    def test_gradient_gated_at_zero(self):
        x = jnp.asarray([-1.0, 2.0], jnp.float32)
        g = jax.grad(lambda v: jnp.sum(fake_quant_unsigned(v, 4.0)))(x)
        np.testing.assert_allclose(np.asarray(g), [0.0, 1.0], atol=1e-6)

    @settings(max_examples=30, deadline=None)
    @given(bits=st.integers(2, 8), n=st.integers(1, 300),
           seed=st.integers(0, 2**31 - 1))
    def test_property_nonneg_and_bounded(self, bits, n, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (n,))
        q = np.asarray(fake_quant_unsigned(x, float(bits)))
        assert (q >= 0).all()
        assert float(q.max(initial=0.0)) <= float(jnp.maximum(x, 0).max()) + 1e-5

    def test_more_bits_less_error(self):
        rng = np.random.default_rng(3)
        x = jnp.abs(_rand(rng, (2048,)))
        errs = []
        for b in (2.0, 4.0, 8.0):
            q = fake_quant_unsigned(x, b)
            errs.append(float(jnp.mean((q - x) ** 2)))
        assert errs[0] > errs[1] > errs[2]
