"""Layer-2 correctness: model shapes, training dynamics, supernet behaviour.

These tests exercise exactly the programs aot.py lowers, so green here means
the HLO artifacts the Rust coordinator loads compute sensible things."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def _batch(bb, n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(0, 1, (n, bb.input_hw, bb.input_hw, bb.input_c))
                    .astype(np.float32))
    y = jnp.asarray(rng.integers(0, bb.num_classes, n).astype(np.int32))
    return x, y


def _bits(bb, b=8.0):
    return jnp.full((bb.num_layers,), b, jnp.float32)


@pytest.fixture(scope="module", params=["vgg_tiny", "mobilenet_tiny"])
def bb(request):
    n_classes = 10 if request.param == "vgg_tiny" else 2
    return M.BACKBONES[request.param](num_classes=n_classes)


class TestGeometry:
    def test_param_offsets_contiguous(self, bb):
        off = 0
        for l in bb.layers:
            assert l.w_offset == off
            off += l.w_size
            assert l.b_offset == off
            off += l.b_size
        assert bb.param_count == off

    def test_macs_positive(self, bb):
        assert all(l.macs > 0 for l in bb.layers)

    def test_init_params_shape_and_determinism(self, bb):
        p1 = M.init_params(bb, seed=0)
        p2 = M.init_params(bb, seed=0)
        assert p1.shape == (bb.param_count,)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))

    def test_vgg_fits_stm32_flash_at_8bit(self):
        vgg = M.vgg_tiny()
        assert vgg.param_count <= 1024 * 1024  # 1 MB flash at int8


class TestForward:
    def test_logits_shape(self, bb):
        p = M.init_params(bb)
        x, _ = _batch(bb, 4)
        logits = M.forward(bb, p, x, _bits(bb), _bits(bb))
        assert logits.shape == (4, bb.num_classes)

    def test_8bit_close_to_fp32_behaviour(self, bb):
        # 8-bit fake-quant should barely move the logits vs 8-bit weights
        # at different activation widths (monotone degradation).
        p = M.init_params(bb)
        x, _ = _batch(bb, 8)
        l8 = M.forward(bb, p, x, _bits(bb, 8.0), _bits(bb, 8.0))
        l2 = M.forward(bb, p, x, _bits(bb, 2.0), _bits(bb, 2.0))
        base = M.forward(bb, p, x, _bits(bb, 8.0), _bits(bb, 8.0))
        err8 = float(jnp.mean((l8 - base) ** 2))
        err2 = float(jnp.mean((l2 - base) ** 2))
        assert err8 <= err2

    def test_mixed_bit_vector_accepted(self, bb):
        p = M.init_params(bb)
        x, _ = _batch(bb, 2)
        wb = jnp.asarray([(2 + i % 7) for i in range(bb.num_layers)], jnp.float32)
        logits = M.forward(bb, p, x, wb, wb)
        assert jnp.isfinite(logits).all()


class TestQatTraining:
    def test_loss_decreases(self, bb):
        step = jax.jit(M.make_qat_train_step(bb))
        p = M.init_params(bb)
        mom = jnp.zeros_like(p)
        x, y = _batch(bb, 32, seed=1)
        wb, ab = _bits(bb, 4.0), _bits(bb, 4.0)
        first = None
        for i in range(30):
            p, mom, loss, acc = step(p, mom, x, y, wb, ab, jnp.float32(0.05))
            if first is None:
                first = float(loss)
        assert float(loss) < first

    def test_eval_matches_forward(self, bb):
        ev = jax.jit(M.make_eval_step(bb))
        p = M.init_params(bb)
        x, y = _batch(bb, 16)
        loss, acc = ev(p, x, y, _bits(bb), _bits(bb))
        assert 0.0 <= float(acc) <= 1.0
        assert np.isfinite(float(loss))


class TestSupernet:
    def test_step_shapes_and_finiteness(self, bb):
        L, K = bb.num_layers, len(M.OPTIONS)
        step = jax.jit(M.make_supernet_train_step(bb))
        p = M.init_params(bb)
        mom = jnp.zeros_like(p)
        aw = jnp.zeros((L, K), jnp.float32)
        aa = jnp.zeros((L, K), jnp.float32)
        x, y = _batch(bb, 16, seed=2)
        cost = jnp.ones((L, K, K), jnp.float32) / (L * K * K)
        out = step(p, mom, aw, aa, x, y, cost,
                   jnp.float32(0.05), jnp.float32(0.1), jnp.float32(1.0))
        p2, mom2, aw2, aa2, loss, ce, comp, acc = out
        assert aw2.shape == (L, K) and aa2.shape == (L, K)
        for t in (loss, ce, comp, acc):
            assert np.isfinite(float(t))

    def test_cost_gradient_steers_alphas(self, bb):
        # With a cost table that monotonically punishes high bitwidths and
        # lambda large, alphas must drift toward low-bit options.
        L, K = bb.num_layers, len(M.OPTIONS)
        step = jax.jit(M.make_supernet_train_step(bb))
        p = M.init_params(bb)
        mom = jnp.zeros_like(p)
        aw = jnp.zeros((L, K), jnp.float32)
        aa = jnp.zeros((L, K), jnp.float32)
        x, y = _batch(bb, 16, seed=3)
        per_bit = jnp.asarray(M.OPTIONS, jnp.float32)
        cost = (per_bit[None, :, None] * per_bit[None, None, :]) * jnp.ones((L, 1, 1))
        cost = cost / jnp.sum(cost)
        for _ in range(20):
            p, mom, aw, aa, *_ = step(p, mom, aw, aa, x, y, cost,
                                      jnp.float32(0.0), jnp.float32(0.5),
                                      jnp.float32(50.0))
        # expected bitwidth decreased vs uniform init
        sm = jax.nn.softmax(aw, axis=1)
        exp_bits = float(jnp.mean(sm @ per_bit))
        assert exp_bits < float(jnp.mean(per_bit))

    def test_zero_lambda_reduces_to_accuracy_only(self, bb):
        L, K = bb.num_layers, len(M.OPTIONS)
        step = jax.jit(M.make_supernet_train_step(bb))
        p = M.init_params(bb)
        mom = jnp.zeros_like(p)
        aw = jnp.zeros((L, K), jnp.float32)
        aa = jnp.zeros((L, K), jnp.float32)
        x, y = _batch(bb, 8, seed=4)
        cost = jnp.ones((L, K, K), jnp.float32)
        out = step(p, mom, aw, aa, x, y, cost,
                   jnp.float32(0.05), jnp.float32(0.1), jnp.float32(0.0))
        _, _, _, _, loss, ce, comp, _ = out
        assert float(comp) == 0.0
        assert abs(float(loss) - float(ce)) < 1e-6
