"""Supernet-specific behaviour: the straight-through hard activation
selection, the DNAS-collapse regression, and the AOT manifest contract.

These pin down exactly the properties the Rust coordinator relies on when
it drives `supernet_train_step` through PJRT."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def _batch(bb, n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(
        rng.uniform(0, 1, (n, bb.input_hw, bb.input_hw, bb.input_c)).astype(np.float32)
    )
    y = jnp.asarray(rng.integers(0, bb.num_classes, n).astype(np.int32))
    return x, y


@pytest.fixture(scope="module")
def bb():
    return M.BACKBONES["vgg_tiny"](num_classes=10)


class TestHardMix:
    def test_forward_uses_argmax_branch(self):
        # _hard_mix must return exactly one-hot in the forward pass.
        logits = jnp.array([0.3, 2.0, -1.0, 0.9])
        mix = M._hard_mix(logits)
        np.testing.assert_allclose(np.asarray(mix), [0.0, 1.0, 0.0, 0.0], atol=1e-6)

    def test_gradient_flows_through_softmax(self):
        # d(mix)/d(logits) must equal the softmax Jacobian row-sums — i.e.
        # nonzero even though the forward value is a constant one-hot.
        logits = jnp.array([0.5, 1.5, -0.5])

        def f(lg):
            return jnp.sum(M._hard_mix(lg) * jnp.array([1.0, 2.0, 3.0]))

        g = jax.grad(f)(logits)
        assert np.abs(np.asarray(g)).sum() > 1e-3

    def test_supernet_forward_equals_argmax_subnet(self, bb):
        # With hard activation selection and near-one-hot weight logits,
        # the supernet forward must equal the plain forward at the argmax
        # configuration.
        L, K = bb.num_layers, len(M.OPTIONS)
        flat = M.init_params(bb, seed=1)
        x, _ = _batch(bb, 4, seed=2)
        idx_w = np.array([3] * L)  # 5-bit weights
        idx_a = np.array([5] * L)  # 7-bit activations
        aw = np.full((L, K), -40.0, np.float32)
        aa = np.full((L, K), -40.0, np.float32)
        aw[np.arange(L), idx_w] = 40.0
        aa[np.arange(L), idx_a] = 40.0
        sup = M.supernet_forward(bb, flat, jnp.asarray(aw), jnp.asarray(aa), x)
        wbits = jnp.asarray([float(M.OPTIONS[i]) for i in idx_w])
        abits = jnp.asarray([float(M.OPTIONS[i]) for i in idx_a])
        sub = M.forward(bb, flat, x, wbits, abits)
        np.testing.assert_allclose(np.asarray(sup), np.asarray(sub), rtol=2e-3, atol=2e-3)


class TestCollapseRegression:
    def test_ce_punishes_low_bit_activation_selection(self, bb):
        # The DNAS-collapse regression: with hard selection, forcing every
        # activation branch to 2 bits must *hurt* the CE of a trained net,
        # giving the alphas a restoring gradient. (A pure soft mixture
        # fails this: CE goes flat in the alpha_a direction once the net
        # co-adapts to the branch average.) Train cheaply with the QAT
        # step at 8-bit, then probe the supernet CE at the two extremes.
        L, K = bb.num_layers, len(M.OPTIONS)
        flat = M.init_params(bb, seed=0)
        mom = jnp.zeros_like(flat)
        x, y = _batch(bb, 32, seed=3)
        qat = jax.jit(M.make_qat_train_step(bb))
        b8 = jnp.full((L,), 8.0)
        for _ in range(40):  # overfit the fixed batch
            flat, mom, loss, acc = qat(flat, mom, x, y, b8, b8, 0.02)
        assert float(acc) > 0.6, f"training probe failed to learn: acc {acc}"

        aw = np.full((L, K), -40.0, np.float32)
        aw[:, -1] = 40.0  # weights pinned at 8-bit for both probes

        def ce_at(aa_val):
            logits = M.supernet_forward(bb, flat, jnp.asarray(aw), aa_val, x)
            logp = jax.nn.log_softmax(logits)
            onehot = jax.nn.one_hot(y, logits.shape[-1], dtype=jnp.float32)
            return float(-jnp.mean(jnp.sum(onehot * logp, axis=-1)))

        lo = np.full((L, K), -40.0, np.float32)
        lo[:, 0] = 40.0  # all 2-bit
        hi = np.full((L, K), -40.0, np.float32)
        hi[:, -1] = 40.0  # all 8-bit
        assert ce_at(jnp.asarray(lo)) > ce_at(jnp.asarray(hi)) + 0.1

    def test_alpha_gradient_nonzero_for_activations(self, bb):
        L, K = bb.num_layers, len(M.OPTIONS)
        flat = M.init_params(bb, seed=0)
        x, y = _batch(bb, 8, seed=4)

        def loss(aa):
            logits = M.supernet_forward(
                bb, flat, jnp.zeros((L, K)), aa, x
            )
            logp = jax.nn.log_softmax(logits)
            onehot = jax.nn.one_hot(y, logits.shape[-1], dtype=jnp.float32)
            return -jnp.mean(jnp.sum(onehot * logp, axis=-1))

        g = jax.grad(loss)(jnp.zeros((L, K)))
        assert float(jnp.abs(g).sum()) > 0.0


class TestManifestContract:
    """The artifacts directory is the Python↔Rust interchange; verify the
    manifest matches what this module would produce today."""

    ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    @pytest.fixture(scope="class")
    def manifest(self):
        path = os.path.join(self.ART, "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts/ not built")
        with open(path) as f:
            return json.load(f)

    def test_options_match(self, manifest):
        assert manifest["options"] == M.OPTIONS

    @pytest.mark.parametrize("name,classes", [("vgg_tiny", 10), ("mobilenet_tiny", 2)])
    def test_geometry_matches(self, manifest, name, classes):
        entry = manifest["backbones"][name]
        bb = M.BACKBONES[name](num_classes=classes)
        assert entry["param_count"] == bb.param_count
        assert entry["num_layers"] == bb.num_layers
        for got, l in zip(entry["layers"], bb.layers):
            assert got["name"] == l.name
            assert got["w_offset"] == l.w_offset
            assert got["w_size"] == l.w_size
            assert got["macs"] == l.macs

    @pytest.mark.parametrize("name,classes", [("vgg_tiny", 10), ("mobilenet_tiny", 2)])
    def test_init_bin_matches_init_params(self, manifest, name, classes):
        entry = manifest["backbones"][name]
        path = os.path.join(self.ART, entry["init"])
        bb = M.BACKBONES[name](num_classes=classes)
        disk = np.fromfile(path, dtype="<f4")
        fresh = np.asarray(M.init_params(bb, seed=0))
        np.testing.assert_allclose(disk, fresh, rtol=1e-6, atol=1e-7)

    @pytest.mark.parametrize(
        "art", ["qat_step", "eval", "infer", "supernet_step"]
    )
    def test_hlo_artifacts_exist_and_parse(self, manifest, art):
        for name in ("vgg_tiny", "mobilenet_tiny"):
            rel = manifest["backbones"][name]["artifacts"][art]
            path = os.path.join(self.ART, rel)
            assert os.path.exists(path), path
            head = open(path).read(200)
            assert "HloModule" in head, f"{path} is not HLO text"
